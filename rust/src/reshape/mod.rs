//! Trace reshaping for system profiling — paper §IV-C.
//!
//! Given the offloading candidates, reshaping produces the CiM view of the
//! execution: offloaded instructions leave the CPU pipeline (their fetch/
//! decode/rename/issue/commit and functional-unit events disappear, their
//! memory accesses disappear), CiM operations appear at the cache level that
//! owns the data, operand moves and result readbacks add compensating
//! accesses, and the speedup-model perf vector is assembled (§V-C2).
//!
//! Candidates extracted from the same IDG tree were already merged by the
//! selection pass (post-order claim), matching the paper's combine step.

pub mod counters;

pub use counters::{CounterSet, NC};

use crate::analyzer::{CimOp, Selection};
use crate::isa::FuncUnit;
use crate::probes::{IState, MemLevel, Trace};

use counters::*;

/// Perf-vector layout (mirrors `constants.py` PERF_*).
pub const NPERF: usize = 6;
pub const P_CYCLES: usize = 0;
pub const P_COMMITTED: usize = 1;
pub const P_REMOVED: usize = 2;
pub const P_CIM_ADD_L1: usize = 3;
pub const P_CIM_ADD_L2: usize = 4;
pub const P_CLOCK_GHZ: usize = 5;

/// The reshaped execution: both counter vectors plus the perf vector.
#[derive(Clone, Debug)]
pub struct Reshaped {
    pub base: CounterSet,
    pub cim: CounterSet,
    pub perf: [f64; NPERF],
    /// instructions removed from the CPU stream
    pub removed: u64,
    /// CiM ops added, by (level, op)
    pub cim_op_count: u64,
}

fn remove_core_events(c: &mut CounterSet, is: &IState) {
    c.dec(C_FETCH, 1.0);
    c.dec(C_DECODE, 1.0);
    c.dec(C_RENAME, 1.0);
    c.dec(C_IQ_READS, 1.0);
    c.dec(C_IQ_WRITES, 1.0);
    c.dec(C_ROB_READS, 1.0);
    c.dec(C_ROB_WRITES, 1.0);
    for s in is.instr.sources().into_iter().flatten() {
        if s < crate::isa::NUM_INT_REGS {
            c.dec(C_INT_RF_READS, 1.0);
        } else {
            c.dec(C_FP_RF_READS, 1.0);
        }
    }
    if let Some(rd) = is.instr.dest() {
        if rd < crate::isa::NUM_INT_REGS {
            c.dec(C_INT_RF_WRITES, 1.0);
        } else {
            c.dec(C_FP_RF_WRITES, 1.0);
        }
    }
    let fu_counter = match is.fu {
        FuncUnit::IntAlu => C_INT_ALU,
        FuncUnit::IntMul => C_INT_MUL,
        FuncUnit::IntDiv => C_INT_DIV,
        FuncUnit::FpAlu => C_FP_ALU,
        FuncUnit::FpMul => C_FP_MUL,
        FuncUnit::FpDiv => C_FP_DIV,
        FuncUnit::Branch => C_BRANCH,
        FuncUnit::MemRead => {
            c.dec(C_LSQ_READS, 1.0);
            C_INT_ALU // address generation ALU op folded into mem path
        }
        FuncUnit::MemWrite => {
            c.dec(C_LSQ_WRITES, 1.0);
            C_INT_ALU
        }
    };
    if !is.instr.op.is_mem() {
        c.dec(fu_counter, 1.0);
    }
}

fn remove_cache_events(c: &mut CounterSet, is: &IState) {
    let Some(m) = is.mem else { return };
    if m.is_store {
        if m.l1_hit {
            c.dec(C_L1D_WRITE_HITS, 1.0);
        } else {
            c.dec(C_L1D_WRITE_MISSES, 1.0);
            if m.l2_hit {
                c.dec(C_L2_READ_HITS, 1.0);
            } else {
                c.dec(C_L2_READ_MISSES, 1.0);
                c.dec(C_DRAM_READS, 1.0);
            }
        }
    } else if m.l1_hit {
        c.dec(C_L1D_READ_HITS, 1.0);
    } else {
        c.dec(C_L1D_READ_MISSES, 1.0);
        if m.l2_hit {
            c.dec(C_L2_READ_HITS, 1.0);
        } else {
            c.dec(C_L2_READ_MISSES, 1.0);
            c.dec(C_DRAM_READS, 1.0);
        }
    }
}

fn cim_counter(level: MemLevel, op: CimOp) -> usize {
    match (level, op) {
        (MemLevel::L1, CimOp::Or) => C_CIM_L1_OR,
        (MemLevel::L1, CimOp::And) => C_CIM_L1_AND,
        (MemLevel::L1, CimOp::Xor) => C_CIM_L1_XOR,
        (MemLevel::L1, CimOp::Add) => C_CIM_L1_ADD,
        (MemLevel::L2, CimOp::Or) => C_CIM_L2_OR,
        (MemLevel::L2, CimOp::And) => C_CIM_L2_AND,
        (MemLevel::L2, CimOp::Xor) => C_CIM_L2_XOR,
        (MemLevel::L2, CimOp::Add) => C_CIM_L2_ADD,
        (MemLevel::Dram, _) => unreachable!("CiM ops never execute in DRAM"),
    }
}

/// Extra cycles a CiM-ADD pays over a plain read at each level, from the
/// array latency model (Fig 11) — used to scale the CiM system's cycle
/// count so leakage tracks execution time.
fn add_latency_extra(cfg: &crate::config::SystemConfig) -> (f64, f64) {
    let (r1, r2) = crate::energy::cfg_rows(cfg);
    let (_, l1) = crate::energy::energy_latency(&r1);
    let (_, l2) = crate::energy::energy_latency(&r2);
    use crate::energy::calib::{OP_ADD, OP_READ};
    (
        (l1[OP_ADD] - l1[OP_READ]).max(0.0),
        (l2[OP_ADD] - l2[OP_READ]).max(0.0),
    )
}

/// Reshape `trace` according to `sel`, producing profiler inputs.
pub fn reshape(trace: &Trace, sel: &Selection, cfg: &crate::config::SystemConfig) -> Reshaped {
    let clock_ghz = cfg.clock_ghz;
    let base = CounterSet::from_trace(trace);
    let mut cim = base.clone();
    let mut removed = 0u64;
    let mut cim_op_count = 0u64;
    let mut cim_add = [0u64; 2]; // L1, L2

    for cand in &sel.candidates {
        // offloaded CiM-op instructions leave the pipeline
        for &m in &cand.members {
            remove_core_events(&mut cim, &trace.ciq[m as usize]);
        }
        // claimed loads disappear (instruction + cache traffic)
        for &l in &cand.loads {
            let is = &trace.ciq[l as usize];
            remove_core_events(&mut cim, is);
            remove_cache_events(&mut cim, is);
        }
        // absorbed store disappears
        if let Some(s) = cand.absorbed_store {
            let is = &trace.ciq[s as usize];
            remove_core_events(&mut cim, is);
            remove_cache_events(&mut cim, is);
        }
        // CiM operations appear at the candidate's level
        for &op in &cand.ops {
            cim[cim_counter(cand.level, op)] += 1.0;
            cim_op_count += 1;
            if op == CimOp::Add {
                cim_add[(cand.level == MemLevel::L2) as usize] += 1;
            }
        }
        // operand moves: read at the source level + write at the exec level
        for _ in 0..cand.moves {
            match cand.level {
                MemLevel::L2 => {
                    cim[C_L1D_READ_HITS] += 1.0;
                    cim[C_L2_WRITE_HITS] += 1.0;
                }
                _ => {
                    cim[C_L2_READ_HITS] += 1.0;
                    cim[C_L1D_WRITE_HITS] += 1.0;
                }
            }
        }
        // readbacks: the CPU still needs the result in a register
        for _ in 0..cand.readbacks {
            match cand.level {
                MemLevel::L2 => cim[C_L2_READ_HITS] += 1.0,
                _ => cim[C_L1D_READ_HITS] += 1.0,
            }
            cim[C_LSQ_READS] += 1.0;
        }
        removed += cand.removed_count();
        // readbacks keep one CPU-side consumer access alive
        removed = removed.saturating_sub(cand.readbacks as u64);
    }

    let perf = [
        trace.cycles as f64,
        trace.committed as f64,
        removed as f64,
        cim_add[0] as f64,
        cim_add[1] as f64,
        clock_ghz,
    ];
    // leakage tracks execution time: the CiM system's cycle counter uses
    // the same constant-CPI estimate the speedup model applies (§V-C2)
    let (extra_l1, extra_l2) = add_latency_extra(cfg);
    let cpi = if trace.committed > 0 {
        trace.cycles as f64 / trace.committed as f64
    } else {
        1.0
    };
    let cycles_cim = (trace.cycles as f64 - removed as f64 * cpi
        + cim_add[0] as f64 * extra_l1
        + cim_add[1] as f64 * extra_l2)
        .max(1.0);
    cim[counters::C_CYCLES] = cycles_cim;

    Reshaped { base, cim, perf, removed, cim_op_count }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyzer::{analyze, LocalityRule};
    use crate::asm::Asm;
    use crate::config::SystemConfig;
    use crate::sim::{simulate, Limits};

    fn pattern_program(reps: usize) -> Asm {
        let mut a = Asm::new("t");
        let buf = a.data.alloc_i32("buf", &[1, 2, 3, 4, 5, 6, 7, 8]);
        a.li(1, buf as i32);
        a.lw(9, 1, 0);
        for _ in 0..reps {
            a.lw(2, 1, 0);
            a.lw(3, 1, 4);
            a.add(4, 2, 3);
            a.sw(4, 1, 8);
        }
        a.halt();
        a
    }

    fn reshaped(reps: usize) -> (Trace, Reshaped) {
        let cfg = SystemConfig::default();
        let t = simulate(&pattern_program(reps).assemble(), &cfg, Limits::default()).unwrap();
        let an = analyze(&t, &cfg, LocalityRule::AnyCache);
        let r = reshape(&t, &an.selection, &cfg);
        (t, r)
    }

    #[test]
    fn conservation_of_instructions() {
        let (t, r) = reshaped(5);
        // removed + remaining fetches == original fetches
        assert_eq!(r.base[C_FETCH], t.committed as f64);
        assert!((r.cim[C_FETCH] + r.removed as f64 - r.base[C_FETCH]).abs() < 1e-9);
    }

    #[test]
    fn cim_ops_appear_and_memory_traffic_drops() {
        let (_, r) = reshaped(5);
        assert!(r.cim_op_count >= 5);
        assert!(r.cim.total_cim_ops() >= 5.0);
        let base_mem: f64 = r.base.0[C_L1D_READ_HITS..=C_DRAM_WRITES].iter().sum();
        let cim_mem: f64 = r.cim.0[C_L1D_READ_HITS..=C_DRAM_WRITES].iter().sum();
        assert!(cim_mem < base_mem, "cim {cim_mem} !< base {base_mem}");
    }

    #[test]
    fn counters_never_negative() {
        let (_, r) = reshaped(8);
        for (i, v) in r.cim.0.iter().enumerate() {
            assert!(*v >= 0.0, "counter {i} negative: {v}");
        }
    }

    #[test]
    fn perf_vector_consistent() {
        let (t, r) = reshaped(4);
        assert_eq!(r.perf[P_CYCLES], t.cycles as f64);
        assert_eq!(r.perf[P_COMMITTED], t.committed as f64);
        assert_eq!(r.perf[P_REMOVED], r.removed as f64);
        assert_eq!(r.perf[P_CIM_ADD_L1] + r.perf[P_CIM_ADD_L2], r.cim_op_count as f64);
        assert_eq!(r.perf[P_CLOCK_GHZ], 1.0);
    }

    #[test]
    fn no_candidates_means_identity() {
        let mut a = Asm::new("t");
        a.li(1, 3);
        a.mul(2, 1, 1);
        a.halt();
        let cfg = SystemConfig::default();
        let t = simulate(&a.assemble(), &cfg, Limits::default()).unwrap();
        let an = analyze(&t, &cfg, LocalityRule::AnyCache);
        let r = reshape(&t, &an.selection, &cfg);
        assert_eq!(r.base, r.cim);
        assert_eq!(r.removed, 0);
    }
}
