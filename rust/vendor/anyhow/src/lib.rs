//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored shim
//! provides exactly the subset eva-cim uses: [`Error`] with a context
//! chain, [`Result`], the [`anyhow!`]/[`bail!`] macros, and the
//! [`Context`] extension trait.  Display semantics mirror the real crate:
//! `{}` prints the outermost message, `{:#}` prints the full chain
//! separated by `: `, and `{:?}` prints a `Caused by:` listing.

use std::fmt;

/// An error with an optional chain of underlying causes.
pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
}

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from any displayable message.
    pub fn msg(m: impl fmt::Display) -> Self {
        Error { msg: m.to_string(), cause: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context(self, c: impl fmt::Display) -> Self {
        Error { msg: c.to_string(), cause: Some(Box::new(self)) }
    }

    /// The chain of messages, outermost first.
    pub fn chain(&self) -> Vec<&str> {
        let mut msgs = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            msgs.push(e.msg.as_str());
            cur = e.cause.as_deref();
        }
        msgs
    }

    /// The innermost message of the chain.
    pub fn root_cause(&self) -> &str {
        self.chain().last().copied().unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if f.alternate() {
            let mut cur = self.cause.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.cause.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        let mut cur = self.cause.as_deref();
        if cur.is_some() {
            f.write_str("\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.cause.as_deref();
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut out: Option<Error> = None;
        for msg in msgs.into_iter().rev() {
            out = Some(Error { msg, cause: out.map(Box::new) });
        }
        out.expect("at least one message")
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T>
    for std::result::Result<T, E>
{
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Result<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// `return Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Err::<(), _>(io_err())
            .with_context(|| "reading manifest")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: missing");
    }

    #[test]
    fn macros_build_errors() {
        let e = anyhow!("bad value {}", 7);
        assert_eq!(e.to_string(), "bad value 7");
        fn f() -> Result<()> {
            bail!("nope");
        }
        assert!(f().is_err());
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "missing");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("empty").unwrap_err();
        assert_eq!(e.to_string(), "empty");
    }
}
