//! Bench: regenerate Fig 16 (CMOS SRAM vs FeFET-RAM energy + performance).
//! Paper shape: FeFET improvements ~50-70% above SRAM, consistent across
//! benchmarks; FeFET also faster thanks to lower CiM op latency.

use eva_cim::coordinator::SweepOptions;
use eva_cim::experiments;
use eva_cim::runtime::{best_backend, PjrtRuntime};

fn main() {
    let mut backend = best_backend(&PjrtRuntime::default_dir());
    let t0 = std::time::Instant::now();
    let table = experiments::fig16(SweepOptions::default(), backend.as_mut())
        .expect("fig16");
    println!("{}", table.render());
    if let Some(stats) = &table.stats {
        eprintln!(
            "{}",
            eva_cim::coordinator::format_stats(stats, table.elapsed_secs)
        );
    }
    println!("[bench] fig16: {:.2}s (backend={})",
             t0.elapsed().as_secs_f64(), backend.name());
}
