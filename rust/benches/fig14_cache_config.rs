//! Bench: regenerate Fig 14 (energy improvement across cache configs
//! c1=32k/256k, c2=64k/256k, c3=64k/2M). Paper shape: larger caches help
//! many applications, but the higher per-op CiM energy erodes the benefit
//! (finding iii).

use eva_cim::coordinator::SweepOptions;
use eva_cim::experiments;
use eva_cim::runtime::{best_backend, PjrtRuntime};

fn main() {
    let mut backend = best_backend(&PjrtRuntime::default_dir());
    let t0 = std::time::Instant::now();
    let table = experiments::fig14(SweepOptions::default(), backend.as_mut())
        .expect("fig14");
    println!("{}", table.render());
    if let Some(stats) = &table.stats {
        eprintln!(
            "{}",
            eva_cim::coordinator::format_stats(stats, table.elapsed_secs)
        );
    }
    println!("[bench] fig14: {:.2}s (51 design points, backend={})",
             t0.elapsed().as_secs_f64(), backend.name());
}
