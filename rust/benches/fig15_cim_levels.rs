//! Bench: regenerate Fig 15 (CiM in L1 only / L2 only / both).
//! Paper shape: L2-only trails because L1 soaks up most accesses and L2
//! CiM ops cost more; both-levels wins.

use eva_cim::coordinator::SweepOptions;
use eva_cim::experiments;
use eva_cim::runtime::{best_backend, PjrtRuntime};

fn main() {
    let mut backend = best_backend(&PjrtRuntime::default_dir());
    let t0 = std::time::Instant::now();
    let table = experiments::fig15(SweepOptions::default(), backend.as_mut())
        .expect("fig15");
    println!("{}", table.render());
    if let Some(stats) = &table.stats {
        eprintln!(
            "{}",
            eva_cim::coordinator::format_stats(stats, table.elapsed_secs)
        );
    }
    println!("[bench] fig15: {:.2}s (backend={})",
             t0.elapsed().as_secs_f64(), backend.name());
}
