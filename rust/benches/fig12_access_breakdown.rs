//! Bench: regenerate Fig 12 (CiM-supported accesses, Eva-CiM vs Jain [23],
//! LCS x20 random inputs). Paper: Eva-CiM ~65% vs [23] ~58% — the IDG finds
//! more convertible accesses than the compile-time pairing. Shape check:
//! Eva-CiM > Jain.

use eva_cim::experiments;

fn main() {
    let t0 = std::time::Instant::now();
    let table = experiments::fig12(20, 0).expect("fig12");
    println!("{}", table.render());
    println!("[bench] fig12: {:.2}s for 20 runs", t0.elapsed().as_secs_f64());
}
