//! Bench: regenerate Table III (cache energy per op, SRAM vs FeFET).
//! Paper anchors: SRAM L1 read 61 pJ … FeFET L2 ADD 205 pJ — reproduced
//! exactly by construction (power-law anchored model).

use eva_cim::experiments;
use eva_cim::util::stats::time_it;

fn main() {
    let table = experiments::table3();
    println!("{}", table.render());
    let (iters, ns) = time_it(|| { let _ = experiments::table3(); }, 10, 200);
    println!("[bench] table3: {:.1} us/iter over {} iters", ns / 1e3, iters);
}
