//! Bench: regenerate Fig 11 (access latency of non-CiM and CiM ops).
//! Paper shape: SRAM logic ≈ read latency; CiM-ADD ≈ read + 4 cycles;
//! FeFET faster across the board.

use eva_cim::experiments;
use eva_cim::util::stats::time_it;

fn main() {
    let table = experiments::fig11();
    println!("{}", table.render());
    let (iters, ns) = time_it(|| { let _ = experiments::fig11(); }, 10, 200);
    println!("[bench] fig11: {:.1} us/iter over {} iters", ns / 1e3, iters);
}
