//! Bench: regenerate Table VI (speedup, energy improvement, breakdown).
//! Paper bands: speedup 1.0-1.5x (BC ~0.99), energy improvement 1.3-6.0x,
//! improvement dominated by the processor side with some negative cache
//! contributions. Our reproduction preserves the shape (who wins, the
//! processor-dominated breakdown, sub-unity stragglers); absolute factors
//! are compressed by hand-compiled codegen (see EXPERIMENTS.md).

use eva_cim::coordinator::SweepOptions;
use eva_cim::experiments;
use eva_cim::runtime::{best_backend, PjrtRuntime};

fn main() {
    let mut backend = best_backend(&PjrtRuntime::default_dir());
    let t0 = std::time::Instant::now();
    let table = experiments::table6(SweepOptions::default(), backend.as_mut())
        .expect("table6");
    println!("{}", table.render());
    if let Some(stats) = &table.stats {
        eprintln!(
            "{}",
            eva_cim::coordinator::format_stats(stats, table.elapsed_secs)
        );
    }
    println!("[bench] table6: {:.2}s (backend={})",
             t0.elapsed().as_secs_f64(), backend.name());
}
