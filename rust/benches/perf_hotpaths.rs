//! Perf bench (§Perf of EXPERIMENTS.md): hot-path throughputs of the three
//! L3 stages, streaming-vs-batch pipeline wall-clock, PJRT-vs-native
//! backend latency per batched evaluation, the sweep result cache
//! (warm resume must be ≥10x faster than cold), warm-trace replay
//! decode (per-record reference vs zero-copy chunk decode vs pipelined
//! multi-lane decode on the same spilled trace), cold-path simulation
//! (the per-commit reference interpreter vs the pre-decoded execution
//! path on the same program), and the offload-planner stage (pricing
//! every candidate group vs a bare delta fold on the same stream).
//!
//! Targets (DESIGN.md §8): simulator ≥ 2 M instr/s, analyzer ≥ 5 M nodes/s,
//! pipelined sim∥analyze beats sequential materialize-then-analyze,
//! PJRT amortized by 256-point batching, warm-cache re-sweep ≥ 10x cold.
//!
//! `cargo bench --bench perf_hotpaths -- --test` runs every section once
//! with small workloads — the CI smoke mode.  The smoke includes a
//! streaming run at an instruction count whose materialized CIQ + IDG
//! forest would not fit a per-worker memory budget under the old batch
//! path, asserting the analysis window stays O(loop body).

use std::time::Instant;

use eva_cim::analyzer::{analyze, analyze_batch, LocalityRule, OnlineAnalyzer};
use eva_cim::asm::Asm;
use eva_cim::config::{CimLevels, SystemConfig, Technology};
use eva_cim::coordinator::trace_store::TraceStore;
use eva_cim::coordinator::{cross, Coordinator, SweepOptions};
use eva_cim::pipeline::run_pipelined;
use eva_cim::planner::{PlanPolicy, PlanSink};
use eva_cim::probes::{IState, TraceSink};
use eva_cim::profiler::{evaluate_native_batch, ProfileInputs};
use eva_cim::reshape::{reshape, reshape_from_deltas, DeltaSink};
use eva_cim::runtime::{NativeBackend, PjrtRuntime};
use eva_cim::sim::{decode, simulate, simulate_reference_into, Limits};
use eva_cim::util::json::Json;
use eva_cim::workloads;

/// Run `body` repeatedly for `secs` (once in quick mode); returns
/// `(iterations, elapsed seconds)`.
fn repeat(quick: bool, secs: f64, mut body: impl FnMut()) -> (u32, f64) {
    let t0 = Instant::now();
    let mut iters = 0u32;
    loop {
        body();
        iters += 1;
        if quick || t0.elapsed().as_secs_f64() >= secs {
            break;
        }
    }
    (iters, t0.elapsed().as_secs_f64())
}

/// A tight convertible loop (memory-resident counter, registers rewritten
/// every iteration): trace length scales freely, live window does not.
fn stream_loop(iters: i32) -> eva_cim::asm::Program {
    let mut a = Asm::new("stream-bench");
    let buf = a.data.alloc_i32("buf", &[7, 9, 0, 0, 0, 0, 0, 0]);
    a.li(1, buf as i32);
    a.li(9, buf as i32 + 16);
    let top = a.label("top");
    a.bind(top);
    a.lw(2, 1, 0);
    a.lw(3, 1, 4);
    a.add(4, 2, 3);
    a.sw(4, 1, 8);
    a.lw(7, 9, 0);
    a.addi(7, 7, 1);
    a.sw(7, 9, 0);
    a.li(8, iters);
    a.bne(7, 8, top);
    a.halt();
    a.assemble()
}

/// Streaming vs batch: (a) wall-clock of pipelined sim∥analyze against
/// sequential materialize → batch-analyze → reshape on the same workload;
/// (b) a streaming-only run at a scale whose materialized trace would not
/// fit a bounded per-worker budget.
fn bench_streaming(quick: bool) {
    let cfg = SystemConfig::preset("c1").unwrap();

    // --- (a) pipelined vs sequential on identical work -------------------
    let cmp_iters = if quick { 120_000 } else { 450_000 }; // ~1M / ~4M instrs
    let prog = stream_loop(cmp_iters);

    // best-of-N wall clocks: a single sample on a shared machine is noise
    let samples = if quick { 1 } else { 2 };
    let mut seq = f64::MAX;
    let mut committed = 0u64;
    let mut cim_seq = None;
    for _ in 0..samples {
        let t0 = Instant::now();
        let trace = simulate(&prog, &cfg, Limits::default()).unwrap();
        let an = analyze_batch(&trace, &cfg, LocalityRule::AnyCache);
        let r_seq = reshape(&trace, &an.selection, &cfg);
        seq = seq.min(t0.elapsed().as_secs_f64());
        committed = trace.committed;
        cim_seq = Some(r_seq.cim);
    }

    let mut piped = f64::MAX;
    let mut peak_window = 0usize;
    let mut cim_pipe = None;
    for _ in 0..samples {
        let t1 = Instant::now();
        let (summary, outcome, deltas) = run_pipelined(
            &prog,
            &cfg,
            Limits::default(),
            LocalityRule::AnyCache,
            DeltaSink::default(),
            None,
        )
        .unwrap();
        let r_pipe = reshape_from_deltas(&summary, &deltas, &cfg);
        piped = piped.min(t1.elapsed().as_secs_f64());
        assert_eq!(summary.committed, committed);
        peak_window = outcome.peak_window;
        cim_pipe = Some(r_pipe.cim);
    }

    assert_eq!(cim_pipe, cim_seq, "streaming must match batch");
    println!(
        "[perf] pipeline: sequential batch {:.0} ms -> pipelined streaming \
         {:.0} ms ({:.2}x) on {:.1} M instrs, window {} ({:.4}% of trace)",
        seq * 1e3,
        piped * 1e3,
        seq / piped.max(1e-9),
        committed as f64 / 1e6,
        peak_window,
        peak_window as f64 / committed as f64 * 100.0
    );
    if !quick {
        // generous margin: the real contract is "overlap never costs";
        // typical wins are 1.2-1.5x, and CI smoke skips this entirely
        assert!(
            piped <= seq * 1.15,
            "pipelined {piped:.3}s must not be slower than sequential {seq:.3}s"
        );
    }

    // --- (b) streaming-only at batch-infeasible scale --------------------
    let big_iters = if quick { 700_000 } else { 2_700_000 }; // ~6.3M / ~24M
    let prog = stream_loop(big_iters);
    let t2 = Instant::now();
    let (summary, outcome, _) = run_pipelined(
        &prog,
        &cfg,
        Limits { max_instructions: 100_000_000 },
        LocalityRule::AnyCache,
        DeltaSink::default(),
        None,
    )
    .unwrap();
    let secs = t2.elapsed().as_secs_f64();
    let ciq_mb = summary.committed as f64 * 136.0 / 1e6;
    println!(
        "[perf] stream-scale: {:.1} M instrs in {:.1} s ({:.2} M instr/s), \
         window {} entries vs ~{:.0} MB materialized CIQ under batch",
        summary.committed as f64 / 1e6,
        secs,
        summary.committed as f64 / secs / 1e6,
        outcome.peak_window,
        ciq_mb
    );
    assert!(
        outcome.peak_window < 256,
        "window {} must stay O(loop body)",
        outcome.peak_window
    );
}

/// Stage-factored sweep vs the legacy per-point analysis loop on a
/// T-tech × P-placement grid sharing one trace.  Emits a machine-readable
/// `BENCH_sweep.json` (schema `BENCH_sweep/4`) with the wall-clocks and
/// the ledger counters — plus the replay-decode entries collected by
/// [`bench_replay`], the cold-path entries from [`bench_sim_decode`], and
/// the planner-stage entries from [`bench_planner`] — so CI can grep the
/// factoring win and diff the key set against the committed snapshot at
/// the repo root.
fn bench_stage_factored(quick: bool, extra: Vec<(&'static str, Json)>) {
    let scale = if quick { 4 } else { 12 };
    let placements = [CimLevels::L1Only, CimLevels::L2Only, CimLevels::Both];
    let techs = [
        Technology::SRAM,
        Technology::FEFET,
        Technology::RRAM,
        Technology::STT_MRAM,
    ];
    let base = SystemConfig::preset("c1").unwrap();
    let mut cfgs = Vec::new();
    for tech in techs {
        for cim in placements {
            let mut c = base.clone().with_tech(tech).with_cim(cim);
            c.name = format!("c1-{}-{}", tech.name(), cim.name());
            cfgs.push(c);
        }
    }
    let points = cross(&["lcs"], &cfgs, LocalityRule::AnyCache);
    let opts = SweepOptions { scale, workers: 2, ..Default::default() };

    // factored: the coordinator groups by trace, then analysis key
    let t0 = Instant::now();
    let (rows, stats) = Coordinator::new(opts.clone())
        .run_sweep_with_stats(&points, &mut NativeBackend)
        .unwrap();
    let factored = t0.elapsed().as_secs_f64();
    assert_eq!(stats.simulator_runs, 1);
    assert_eq!(stats.analyses_run, placements.len() as u64);

    // unfactored reference: one simulation (the legacy trace memo), then
    // one full analysis pass per design point — the old O(T*P) loop
    let t1 = Instant::now();
    let prog = workloads::build("lcs", scale, opts.seed).unwrap();
    let trace = simulate(&prog, &base, Limits::default()).unwrap();
    let mut checksum = 0.0f64;
    for p in &points {
        let mut oa = OnlineAnalyzer::new(
            p.config.cim_levels,
            p.rule,
            DeltaSink::default(),
        );
        for is in &trace.ciq {
            oa.push(is);
        }
        let (_, deltas) = oa.finish();
        let r = reshape_from_deltas(&trace.summary(), &deltas, &p.config);
        checksum += r.removed as f64;
    }
    let unfactored = t1.elapsed().as_secs_f64();
    assert!(checksum >= 0.0);

    println!(
        "[perf] stage-factored sweep: {} points ({} techs x {} placements) \
         in {:.1} ms vs {:.1} ms per-point analysis ({:.2}x) | {} analyses \
         run, {} replays skipped",
        points.len(),
        techs.len(),
        placements.len(),
        factored * 1e3,
        unfactored * 1e3,
        unfactored / factored.max(1e-9),
        stats.analyses_run,
        stats.replays_skipped,
    );
    assert_eq!(rows.len(), points.len());

    let mut entries: Vec<(&'static str, Json)> = vec![
        ("schema", "BENCH_sweep/4".into()),
        ("points", (points.len() as u64).into()),
        ("techs", (techs.len() as u64).into()),
        ("placements", (placements.len() as u64).into()),
        ("factored_ms", (factored * 1e3).into()),
        ("unfactored_ms", (unfactored * 1e3).into()),
        ("simulator_runs", stats.simulator_runs.into()),
        ("analyses_run", stats.analyses_run.into()),
        ("analyses_cached", stats.analyses_cached.into()),
        ("replays_skipped", stats.replays_skipped.into()),
    ];
    entries.extend(extra);
    let doc = Json::obj(entries).dump();
    if let Err(e) = std::fs::write("BENCH_sweep.json", &doc) {
        eprintln!("warning: could not write BENCH_sweep.json: {e}");
    } else {
        println!("[perf] stage-factored counters written to BENCH_sweep.json");
    }
}

/// Warm-trace replay decode on one spilled trace, feeding an O(1)
/// counting sink so decode cost dominates: the per-record reference
/// decoder vs the zero-copy chunk decoder vs pipelined 4-lane decode.
/// Then the same decode path through the coordinator: a first sweep pass
/// spills the trace, a second pass over fresh placements replays it with
/// the analyzer fan-out split across idle workers — the
/// `replay_chunks_decoded` / `replay_lanes_split` ledger counters prove
/// the parallel path executed.  Returns the `BENCH_sweep.json` entries.
fn bench_replay(quick: bool) -> Vec<(&'static str, Json)> {
    struct CountSink(u64);
    impl TraceSink for CountSink {
        fn on_commit(&mut self, _is: IState) {
            self.0 += 1;
        }
    }

    let dir = std::env::temp_dir()
        .join(format!("eva-cim-bench-replay-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let store = TraceStore::open(&dir).unwrap();
    let cfg = SystemConfig::preset("c1").unwrap();
    let iters = if quick { 40_000 } else { 200_000 }; // ~360k / ~1.8M records
    let prog = stream_loop(iters);
    let trace =
        simulate(&prog, &cfg, Limits { max_instructions: 100_000_000 })
            .unwrap();
    let committed = trace.committed;
    store.store("bench", &trace).unwrap();
    drop(trace);

    // best-of-N; lanes == 0 selects the per-record reference decoder
    let samples = if quick { 1 } else { 3 };
    let mut time = |lanes: usize| -> (f64, u64) {
        let mut best = f64::MAX;
        let mut chunks = 0u64;
        for _ in 0..samples {
            let mut sink = CountSink(0);
            let t0 = Instant::now();
            if lanes == 0 {
                store.replay_reference("bench", &mut sink).unwrap();
            } else {
                let (_, c) =
                    store.replay_with("bench", &mut sink, lanes).unwrap();
                chunks = c;
            }
            best = best.min(t0.elapsed().as_secs_f64());
            assert_eq!(sink.0, committed, "replay must feed every record");
        }
        (best, chunks)
    };
    let (ref_s, _) = time(0);
    let (zc_s, chunks) = time(1);
    let (par_s, _) = time(4);
    println!(
        "[perf] replay: {:.2} M records / {} chunks: reference {:.1} ms -> \
         zero-copy {:.1} ms ({:.2}x) -> 4-lane {:.1} ms ({:.2}x)",
        committed as f64 / 1e6,
        chunks,
        ref_s * 1e3,
        zc_s * 1e3,
        ref_s / zc_s.max(1e-9),
        par_s * 1e3,
        ref_s / par_s.max(1e-9),
    );
    if !quick {
        // the real contract is byte-identity at any lane count (pinned by
        // rust/tests/replay_parallel.rs); perf-wise the 4-lane decode must
        // at minimum beat the old per-record path it replaced
        assert!(
            par_s <= ref_s,
            "4-lane replay {par_s:.3}s slower than reference {ref_s:.3}s"
        );
    }

    // the coordinator end of the same path: pass 1 spills the trace,
    // pass 2 stages two new placements against it — one disk replay,
    // fan-out split across passes, multi-lane decode inside each
    let cache = dir.join("sweep-cache");
    let scale = if quick { 2 } else { 8 };
    let cfg_for = |cim: CimLevels| {
        let mut c = SystemConfig::preset("c1").unwrap().with_cim(cim);
        c.name = format!("c1-{}", cim.name());
        c
    };
    let opts = SweepOptions {
        scale,
        workers: 4,
        replay_threads: 4,
        cache_dir: Some(cache),
        resume: true,
        ..Default::default()
    };
    let cold =
        cross(&["lcs"], &[cfg_for(CimLevels::L1Only)], LocalityRule::AnyCache);
    Coordinator::new(opts.clone())
        .run_sweep_with_stats(&cold, &mut NativeBackend)
        .unwrap();
    let warm_cfgs = [cfg_for(CimLevels::L2Only), cfg_for(CimLevels::Both)];
    let points = cross(&["lcs"], &warm_cfgs, LocalityRule::AnyCache);
    let (_, stats) = Coordinator::new(opts)
        .run_sweep_with_stats(&points, &mut NativeBackend)
        .unwrap();
    assert_eq!(stats.simulator_runs, 0, "second pass must not simulate");
    assert_eq!(stats.trace_disk_hits, 1);
    assert!(stats.replay_chunks_decoded > 0, "decode counter must move");
    assert_eq!(stats.replay_lanes_split, 2, "both analysis lanes must split");
    println!(
        "[perf] replay-sweep: {} chunks decoded across {} split lanes \
         (0 simulations on the second pass)",
        stats.replay_chunks_decoded, stats.replay_lanes_split,
    );
    std::fs::remove_dir_all(&dir).ok();

    vec![
        ("replay_records", committed.into()),
        ("replay_chunks", chunks.into()),
        ("replay_reference_ms", (ref_s * 1e3).into()),
        ("replay_zero_copy_ms", (zc_s * 1e3).into()),
        ("replay_lanes4_ms", (par_s * 1e3).into()),
        ("replay_chunks_decoded", stats.replay_chunks_decoded.into()),
        ("replay_lanes_split", stats.replay_lanes_split.into()),
    ]
}

/// Cold-path dispatch: the per-commit reference interpreter
/// (`simulate_reference_into`) vs the pre-decoded execution path
/// (`decode::simulate_decoded_into`) on the same `stream_loop` program,
/// both feeding a no-op sink so opcode dispatch and operand routing
/// dominate the measurement.  The summaries must be equal — full
/// byte-identity (commit streams, reports) is pinned by
/// `rust/tests/sim_differential.rs`; here only the wall-clocks differ.
/// Returns the `BENCH_sweep.json` entries.
fn bench_sim_decode(quick: bool) -> Vec<(&'static str, Json)> {
    struct NullSink;
    impl TraceSink for NullSink {
        fn on_commit(&mut self, _is: IState) {}
    }

    let cfg = SystemConfig::preset("c1").unwrap();
    let iters = if quick { 60_000 } else { 500_000 }; // ~540k / ~4.5M instrs
    let prog = stream_loop(iters);
    let limits = Limits { max_instructions: 100_000_000 };

    let samples = if quick { 1 } else { 3 };
    let mut time = |reference: bool| {
        let mut best = f64::MAX;
        let mut summary = None;
        for _ in 0..samples {
            let mut sink = NullSink;
            let t0 = Instant::now();
            let s = if reference {
                simulate_reference_into(&prog, &cfg, limits, &mut sink)
            } else {
                decode::simulate_decoded_into(&prog, &cfg, limits, &mut sink)
            }
            .unwrap();
            best = best.min(t0.elapsed().as_secs_f64());
            summary = Some(s);
        }
        (best, summary.unwrap())
    };
    let (ref_s, ref_sum) = time(true);
    let (dec_s, dec_sum) = time(false);
    assert_eq!(ref_sum, dec_sum, "decoded path diverged from the reference");
    println!(
        "[perf] sim-decode: {:.2} M instrs: reference {:.1} ms -> \
         pre-decoded {:.1} ms ({:.2}x)",
        ref_sum.committed as f64 / 1e6,
        ref_s * 1e3,
        dec_s * 1e3,
        ref_s / dec_s.max(1e-9),
    );

    vec![
        ("sim_reference_ms", (ref_s * 1e3).into()),
        ("sim_decoded_ms", (dec_s * 1e3).into()),
    ]
}

/// Planner-stage cost on one pipelined run: a bare `DeltaSink` fold (no
/// planning), the accept-all `PlanSink` (must fold identical deltas and
/// cost next to nothing on top), and the profitability `PlanSink` (prices
/// every candidate group against the device model).  Both policies must
/// judge the same candidate stream.  Returns the `BENCH_sweep.json`
/// entries.
fn bench_planner(quick: bool) -> Vec<(&'static str, Json)> {
    let cfg = SystemConfig::preset("c1").unwrap();
    let iters = if quick { 60_000 } else { 400_000 }; // ~540k / ~3.6M instrs
    let prog = stream_loop(iters);
    let limits = Limits { max_instructions: 100_000_000 };
    let samples = if quick { 1 } else { 3 };

    let mut bare = f64::MAX;
    let mut removed_bare = 0u64;
    for _ in 0..samples {
        let t0 = Instant::now();
        let (summary, _, deltas) = run_pipelined(
            &prog,
            &cfg,
            limits,
            LocalityRule::AnyCache,
            DeltaSink::default(),
            None,
        )
        .unwrap();
        bare = bare.min(t0.elapsed().as_secs_f64());
        removed_bare = reshape_from_deltas(&summary, &deltas, &cfg).removed;
    }

    let mut time_policy = |policy: PlanPolicy| {
        let knobs = policy.default_knobs();
        let mut best = f64::MAX;
        let mut out = None;
        for _ in 0..samples {
            let t0 = Instant::now();
            let (summary, _, sink) = run_pipelined(
                &prog,
                &cfg,
                limits,
                LocalityRule::AnyCache,
                PlanSink::new(&cfg, policy, knobs),
                None,
            )
            .unwrap();
            best = best.min(t0.elapsed().as_secs_f64());
            let (plan, deltas) = sink.finish();
            let removed = reshape_from_deltas(&summary, &deltas, &cfg).removed;
            out = Some((plan, removed));
        }
        let (plan, removed) = out.unwrap();
        (best, plan, removed)
    };
    let (acc_s, acc_plan, removed_acc) = time_policy(PlanPolicy::AcceptAll);
    let (prof_s, prof_plan, _) = time_policy(PlanPolicy::Profitability);

    assert_eq!(
        removed_acc, removed_bare,
        "accept-all must fold the same deltas as a bare sink"
    );
    assert_eq!(acc_plan.groups_rejected(), 0, "accept-all never rejects");
    assert_eq!(
        prof_plan.groups_accepted() + prof_plan.groups_rejected(),
        acc_plan.groups_accepted(),
        "both policies must judge the same candidate stream"
    );
    println!(
        "[perf] planner: {} groups: bare fold {:.1} ms -> accept-all \
         {:.1} ms ({:.2}x) -> profitability {:.1} ms ({:.2}x), \
         {} rejected ({:.1} pJ declined)",
        acc_plan.groups_accepted(),
        bare * 1e3,
        acc_s * 1e3,
        acc_s / bare.max(1e-9),
        prof_s * 1e3,
        prof_s / bare.max(1e-9),
        prof_plan.groups_rejected(),
        prof_plan.rejected_energy_pj(),
    );

    vec![
        ("plan_bare_ms", (bare * 1e3).into()),
        ("plan_accept_all_ms", (acc_s * 1e3).into()),
        ("plan_profitability_ms", (prof_s * 1e3).into()),
        ("plan_groups_seen", acc_plan.groups_accepted().into()),
        ("plan_groups_rejected", prof_plan.groups_rejected().into()),
    ]
}

fn bench_cache_resume(quick: bool) {
    let dir = std::env::temp_dir()
        .join(format!("eva-cim-bench-cache-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let scale = if quick { 2 } else { 8 };
    let mut configs = Vec::new();
    for preset in ["c1", "c2"] {
        for tech in Technology::all() {
            let mut c = SystemConfig::preset(preset).unwrap().with_tech(tech);
            c.name = format!("{preset}-{}", tech.name());
            configs.push(c);
        }
    }
    let points = cross(&["lcs", "km", "bfs"], &configs, LocalityRule::AnyCache);
    let opts = SweepOptions {
        scale,
        workers: 2,
        cache_dir: Some(dir.clone()),
        resume: true,
        ..Default::default()
    };

    let t0 = Instant::now();
    let (cold_rows, cold_stats) = Coordinator::new(opts.clone())
        .run_sweep_with_stats(&points, &mut NativeBackend)
        .unwrap();
    let cold = t0.elapsed().as_secs_f64();

    let t1 = Instant::now();
    let (warm_rows, warm_stats) = Coordinator::new(opts)
        .run_sweep_with_stats(&points, &mut NativeBackend)
        .unwrap();
    let warm = t1.elapsed().as_secs_f64();

    assert_eq!(cold_rows.len(), warm_rows.len());
    assert_eq!(warm_stats.simulator_runs, 0, "warm resume must not simulate");
    assert_eq!(warm_stats.rows_from_cache, points.len());
    let ratio = cold / warm.max(1e-9);
    println!(
        "[perf] sweep-cache: cold {:.1} ms ({} sims) -> warm {:.2} ms \
         ({} cached): {:.0}x",
        cold * 1e3,
        cold_stats.simulator_runs,
        warm * 1e3,
        warm_stats.rows_from_cache,
        ratio
    );
    if !quick {
        assert!(
            ratio >= 10.0,
            "warm-cache re-sweep only {ratio:.1}x faster than cold (want >= 10x)"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

fn main() {
    let quick = std::env::args().any(|a| a == "--test");
    let cfg = SystemConfig::preset("c1").unwrap();
    let prog = workloads::build("lcs", if quick { 2 } else { 4 }, 3).unwrap();

    // --- simulator throughput -------------------------------------------
    let mut committed = 0u64;
    let (runs, secs) = repeat(quick, 2.0, || {
        let t = simulate(&prog, &cfg, Limits::default()).unwrap();
        committed += t.committed;
    });
    println!(
        "[perf] simulator: {:.2} M instr/s ({runs} runs)",
        committed as f64 / secs / 1e6
    );

    // --- analyzer throughput ---------------------------------------------
    let trace = simulate(&prog, &cfg, Limits::default()).unwrap();
    let mut nodes = 0u64;
    let (aruns, asecs) = repeat(quick, 2.0, || {
        let an = analyze(&trace, &cfg, LocalityRule::AnyCache);
        nodes += an.idg_nodes.0;
    });
    println!(
        "[perf] analyzer: {:.2} M IDG nodes/s ({aruns} runs)",
        nodes as f64 / asecs / 1e6
    );

    // --- reshaping + native profile ---------------------------------------
    let analysis = analyze(&trace, &cfg, LocalityRule::AnyCache);
    let (rruns, rsecs) = repeat(quick, 1.0, || {
        let r = reshape(&trace, &analysis.selection, &cfg);
        let _ = evaluate_native_batch(&[ProfileInputs::new(&cfg, &r)]);
    });
    println!(
        "[perf] reshape+native-profile: {:.1} us/design-point",
        rsecs * 1e6 / rruns as f64
    );

    // --- streaming pipeline: pipelined vs batch, and at scale --------------
    bench_streaming(quick);

    // --- warm-trace replay: reference vs zero-copy vs multi-lane decode ----
    let mut extra = bench_replay(quick);

    // --- cold-path simulation: reference interpreter vs pre-decoded --------
    extra.extend(bench_sim_decode(quick));

    // --- offload planner: accept-all vs profitability pricing --------------
    extra.extend(bench_planner(quick));

    // --- stage-factored sweep: shared analysis across tech variants --------
    bench_stage_factored(quick, extra);

    // --- sweep result cache: cold vs warm resume ---------------------------
    bench_cache_resume(quick);

    // --- backend latency: PJRT batched vs native ---------------------------
    let reshaped = reshape(&trace, &analysis.selection, &cfg);
    let one = ProfileInputs::new(&cfg, &reshaped);
    match PjrtRuntime::load(&PjrtRuntime::default_dir()) {
        Err(e) => println!("[perf] pjrt: skipped ({e:#})"),
        Ok(mut rt) => {
            let full: Vec<ProfileInputs> =
                (0..rt.batch).map(|_| one.clone()).collect();
            // warm-up compile/execute
            rt.evaluate_profile(&full[..1].to_vec()).unwrap();
            let (eruns, esecs) = repeat(quick, 2.0, || {
                rt.evaluate_profile(&full).unwrap();
            });
            let per_batch = esecs / eruns as f64;
            println!(
                "[perf] pjrt: {:.2} ms/execute for {} points -> {:.1} us/point",
                per_batch * 1e3,
                rt.batch,
                per_batch * 1e6 / rt.batch as f64
            );
            let (nruns, nsecs) = repeat(quick, 1.0, || {
                let _ = evaluate_native_batch(&full);
            });
            let native_batch = nsecs / nruns as f64;
            println!(
                "[perf] native: {:.2} ms/batch of {} -> {:.1} us/point",
                native_batch * 1e3,
                rt.batch,
                native_batch * 1e6 / rt.batch as f64
            );
        }
    }
}
