//! Perf bench (§Perf of EXPERIMENTS.md): hot-path throughputs of the three
//! L3 stages plus PJRT-vs-native backend latency per batched evaluation.
//!
//! Targets (DESIGN.md §8): simulator ≥ 2 M instr/s, analyzer ≥ 5 M nodes/s,
//! PJRT amortized by 256-point batching.

use std::time::Instant;

use eva_cim::analyzer::{analyze, LocalityRule};
use eva_cim::config::SystemConfig;
use eva_cim::profiler::{evaluate_native_batch, ProfileInputs};
use eva_cim::reshape::reshape;
use eva_cim::runtime::PjrtRuntime;
use eva_cim::sim::{simulate, Limits};
use eva_cim::workloads;

fn main() {
    let cfg = SystemConfig::preset("c1").unwrap();
    let prog = workloads::build("lcs", 4, 3).unwrap();

    // --- simulator throughput -------------------------------------------
    let t0 = Instant::now();
    let mut committed = 0u64;
    let mut runs = 0u32;
    while t0.elapsed().as_secs_f64() < 2.0 {
        let t = simulate(&prog, &cfg, Limits::default()).unwrap();
        committed += t.committed;
        runs += 1;
    }
    let sim_rate = committed as f64 / t0.elapsed().as_secs_f64();
    println!("[perf] simulator: {:.2} M instr/s ({runs} runs)", sim_rate / 1e6);

    // --- analyzer throughput ---------------------------------------------
    let trace = simulate(&prog, &cfg, Limits::default()).unwrap();
    let t1 = Instant::now();
    let mut nodes = 0u64;
    let mut aruns = 0u32;
    while t1.elapsed().as_secs_f64() < 2.0 {
        let an = analyze(&trace, &cfg, LocalityRule::AnyCache);
        nodes += an.idg_nodes.0;
        aruns += 1;
    }
    let an_rate = nodes as f64 / t1.elapsed().as_secs_f64();
    println!("[perf] analyzer: {:.2} M IDG nodes/s ({aruns} runs)", an_rate / 1e6);

    // --- reshaping + native profile ---------------------------------------
    let analysis = analyze(&trace, &cfg, LocalityRule::AnyCache);
    let t2 = Instant::now();
    let mut rruns = 0u32;
    while t2.elapsed().as_secs_f64() < 1.0 {
        let r = reshape(&trace, &analysis.selection, &cfg);
        let _ = evaluate_native_batch(&[ProfileInputs::new(&cfg, &r)]);
        rruns += 1;
    }
    println!(
        "[perf] reshape+native-profile: {:.1} us/design-point",
        t2.elapsed().as_micros() as f64 / rruns as f64
    );

    // --- backend latency: PJRT batched vs native ---------------------------
    let reshaped = reshape(&trace, &analysis.selection, &cfg);
    let one = ProfileInputs::new(&cfg, &reshaped);
    match PjrtRuntime::load(&PjrtRuntime::default_dir()) {
        Err(e) => println!("[perf] pjrt: skipped ({e:#})"),
        Ok(mut rt) => {
            let full: Vec<ProfileInputs> =
                (0..rt.batch).map(|_| one.clone()).collect();
            // warm-up compile/execute
            rt.evaluate_profile(&full[..1].to_vec()).unwrap();
            let t3 = Instant::now();
            let mut eruns = 0u32;
            while t3.elapsed().as_secs_f64() < 2.0 {
                rt.evaluate_profile(&full).unwrap();
                eruns += 1;
            }
            let per_batch = t3.elapsed().as_secs_f64() / eruns as f64;
            println!(
                "[perf] pjrt: {:.2} ms/execute for {} points -> {:.1} us/point",
                per_batch * 1e3,
                rt.batch,
                per_batch * 1e6 / rt.batch as f64
            );
            let t4 = Instant::now();
            let mut nruns = 0u32;
            while t4.elapsed().as_secs_f64() < 1.0 {
                let _ = evaluate_native_batch(&full);
                nruns += 1;
            }
            let native_batch = t4.elapsed().as_secs_f64() / nruns as f64;
            println!(
                "[perf] native: {:.2} ms/batch of {} -> {:.1} us/point",
                native_batch * 1e3,
                rt.batch,
                native_batch * 1e6 / rt.batch as f64
            );
        }
    }
}
