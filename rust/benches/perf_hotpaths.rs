//! Perf bench (§Perf of EXPERIMENTS.md): hot-path throughputs of the three
//! L3 stages, PJRT-vs-native backend latency per batched evaluation, and
//! the sweep result cache (warm resume must be ≥10x faster than cold).
//!
//! Targets (DESIGN.md §8): simulator ≥ 2 M instr/s, analyzer ≥ 5 M nodes/s,
//! PJRT amortized by 256-point batching, warm-cache re-sweep ≥ 10x cold.
//!
//! `cargo bench --bench perf_hotpaths -- --test` runs every section once
//! with tiny workloads — the CI smoke mode that keeps this target
//! compiling and running without spending bench-grade time.

use std::time::Instant;

use eva_cim::analyzer::{analyze, LocalityRule};
use eva_cim::config::{SystemConfig, Technology};
use eva_cim::coordinator::{cross, Coordinator, SweepOptions};
use eva_cim::profiler::{evaluate_native_batch, ProfileInputs};
use eva_cim::reshape::reshape;
use eva_cim::runtime::{NativeBackend, PjrtRuntime};
use eva_cim::sim::{simulate, Limits};
use eva_cim::workloads;

/// Run `body` repeatedly for `secs` (once in quick mode); returns
/// `(iterations, elapsed seconds)`.
fn repeat(quick: bool, secs: f64, mut body: impl FnMut()) -> (u32, f64) {
    let t0 = Instant::now();
    let mut iters = 0u32;
    loop {
        body();
        iters += 1;
        if quick || t0.elapsed().as_secs_f64() >= secs {
            break;
        }
    }
    (iters, t0.elapsed().as_secs_f64())
}

fn bench_cache_resume(quick: bool) {
    let dir = std::env::temp_dir()
        .join(format!("eva-cim-bench-cache-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let scale = if quick { 2 } else { 8 };
    let mut configs = Vec::new();
    for preset in ["c1", "c2"] {
        for tech in Technology::all() {
            let mut c = SystemConfig::preset(preset).unwrap().with_tech(tech);
            c.name = format!("{preset}-{}", tech.name());
            configs.push(c);
        }
    }
    let points = cross(&["lcs", "km", "bfs"], &configs, LocalityRule::AnyCache);
    let opts = SweepOptions {
        scale,
        workers: 2,
        cache_dir: Some(dir.clone()),
        resume: true,
        ..Default::default()
    };

    let t0 = Instant::now();
    let (cold_rows, cold_stats) = Coordinator::new(opts.clone())
        .run_sweep_with_stats(&points, &mut NativeBackend)
        .unwrap();
    let cold = t0.elapsed().as_secs_f64();

    let t1 = Instant::now();
    let (warm_rows, warm_stats) = Coordinator::new(opts)
        .run_sweep_with_stats(&points, &mut NativeBackend)
        .unwrap();
    let warm = t1.elapsed().as_secs_f64();

    assert_eq!(cold_rows.len(), warm_rows.len());
    assert_eq!(warm_stats.simulator_runs, 0, "warm resume must not simulate");
    assert_eq!(warm_stats.rows_from_cache, points.len());
    let ratio = cold / warm.max(1e-9);
    println!(
        "[perf] sweep-cache: cold {:.1} ms ({} sims) -> warm {:.2} ms \
         ({} cached): {:.0}x",
        cold * 1e3,
        cold_stats.simulator_runs,
        warm * 1e3,
        warm_stats.rows_from_cache,
        ratio
    );
    if !quick {
        assert!(
            ratio >= 10.0,
            "warm-cache re-sweep only {ratio:.1}x faster than cold (want >= 10x)"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

fn main() {
    let quick = std::env::args().any(|a| a == "--test");
    let cfg = SystemConfig::preset("c1").unwrap();
    let prog = workloads::build("lcs", if quick { 2 } else { 4 }, 3).unwrap();

    // --- simulator throughput -------------------------------------------
    let mut committed = 0u64;
    let (runs, secs) = repeat(quick, 2.0, || {
        let t = simulate(&prog, &cfg, Limits::default()).unwrap();
        committed += t.committed;
    });
    println!(
        "[perf] simulator: {:.2} M instr/s ({runs} runs)",
        committed as f64 / secs / 1e6
    );

    // --- analyzer throughput ---------------------------------------------
    let trace = simulate(&prog, &cfg, Limits::default()).unwrap();
    let mut nodes = 0u64;
    let (aruns, asecs) = repeat(quick, 2.0, || {
        let an = analyze(&trace, &cfg, LocalityRule::AnyCache);
        nodes += an.idg_nodes.0;
    });
    println!(
        "[perf] analyzer: {:.2} M IDG nodes/s ({aruns} runs)",
        nodes as f64 / asecs / 1e6
    );

    // --- reshaping + native profile ---------------------------------------
    let analysis = analyze(&trace, &cfg, LocalityRule::AnyCache);
    let (rruns, rsecs) = repeat(quick, 1.0, || {
        let r = reshape(&trace, &analysis.selection, &cfg);
        let _ = evaluate_native_batch(&[ProfileInputs::new(&cfg, &r)]);
    });
    println!(
        "[perf] reshape+native-profile: {:.1} us/design-point",
        rsecs * 1e6 / rruns as f64
    );

    // --- sweep result cache: cold vs warm resume ---------------------------
    bench_cache_resume(quick);

    // --- backend latency: PJRT batched vs native ---------------------------
    let reshaped = reshape(&trace, &analysis.selection, &cfg);
    let one = ProfileInputs::new(&cfg, &reshaped);
    match PjrtRuntime::load(&PjrtRuntime::default_dir()) {
        Err(e) => println!("[perf] pjrt: skipped ({e:#})"),
        Ok(mut rt) => {
            let full: Vec<ProfileInputs> =
                (0..rt.batch).map(|_| one.clone()).collect();
            // warm-up compile/execute
            rt.evaluate_profile(&full[..1].to_vec()).unwrap();
            let (eruns, esecs) = repeat(quick, 2.0, || {
                rt.evaluate_profile(&full).unwrap();
            });
            let per_batch = esecs / eruns as f64;
            println!(
                "[perf] pjrt: {:.2} ms/execute for {} points -> {:.1} us/point",
                per_batch * 1e3,
                rt.batch,
                per_batch * 1e6 / rt.batch as f64
            );
            let (nruns, nsecs) = repeat(quick, 1.0, || {
                let _ = evaluate_native_batch(&full);
            });
            let native_batch = nsecs / nruns as f64;
            println!(
                "[perf] native: {:.2} ms/batch of {} -> {:.1} us/point",
                native_batch * 1e3,
                rt.batch,
                native_batch * 1e6 / rt.batch as f64
            );
        }
    }
}
