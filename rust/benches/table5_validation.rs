//! Bench: regenerate Table V (Eva-CiM vs array-only/DESTINY energy on LCS).
//! Paper: ~24% deviation for both CiM and non-CiM instructions — Eva-CiM
//! sits above the array-only estimate because it adds hierarchy effects.

use eva_cim::experiments;
use eva_cim::runtime::{best_backend, PjrtRuntime};

fn main() {
    let mut backend = best_backend(&PjrtRuntime::default_dir());
    let t0 = std::time::Instant::now();
    let table = experiments::table5(backend.as_mut(), 0).expect("table5");
    println!("{}", table.render());
    println!("[bench] table5: {:.2}s end-to-end (backend={})",
             t0.elapsed().as_secs_f64(), backend.name());
}
