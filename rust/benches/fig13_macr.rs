//! Bench: regenerate Fig 13 (MACR per benchmark + L1/other breakdown).
//! Paper shape: MACR varies widely across benchmarks; data-intensive is not
//! necessarily CiM-convertible (finding ii); most convertible data sits in L1.

use eva_cim::coordinator::SweepOptions;
use eva_cim::experiments;

fn main() {
    let t0 = std::time::Instant::now();
    let table = experiments::fig13(SweepOptions::default()).expect("fig13");
    println!("{}", table.render());
    if let Some(stats) = &table.stats {
        eprintln!(
            "{}",
            eva_cim::coordinator::format_stats(stats, table.elapsed_secs)
        );
    }
    println!("[bench] fig13: {:.2}s for 17 benchmarks", t0.elapsed().as_secs_f64());
}
