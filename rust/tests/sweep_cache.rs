//! The resumable-sweep contract: a resumed sweep produces byte-identical
//! rows to a cold sweep, a fully-warm resume performs *zero* simulator
//! invocations, supersets of a prior sweep only compute the delta, and the
//! cache keys are stable, content-sensitive functions of the design point.

use std::path::PathBuf;

use eva_cim::analyzer::LocalityRule;
use eva_cim::config::{CimLevels, SystemConfig, Technology};
use eva_cim::coordinator::{
    cross, key, persist, Coordinator, SweepOptions, SweepPoint, SweepRow,
};
use eva_cim::runtime::NativeBackend;
use eva_cim::util::proptest::check;
use eva_cim::util::rng::Rng;

fn tmp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("eva-cim-sweep-{tag}-{}", std::process::id()))
}

fn opts(dir: Option<PathBuf>, resume: bool) -> SweepOptions {
    SweepOptions {
        scale: 4,
        workers: 2,
        cache_dir: dir,
        resume,
        ..Default::default()
    }
}

fn two_by_two_points() -> Vec<SweepPoint> {
    let cfgs = [
        SystemConfig::preset("c1").unwrap(),
        SystemConfig::preset("c2").unwrap(),
    ];
    cross(&["lcs", "km"], &cfgs, LocalityRule::AnyCache)
}

fn dump_rows(rows: &[SweepRow]) -> Vec<String> {
    rows.iter().map(|r| persist::row_to_json(r).dump()).collect()
}

#[test]
fn resumed_sweep_is_byte_identical_and_simulates_nothing() {
    let dir = tmp_dir("identical");
    std::fs::remove_dir_all(&dir).ok();
    let points = two_by_two_points();

    // reference: plain in-memory sweep, no cache involved at all
    let (plain, _) = Coordinator::new(opts(None, false))
        .run_sweep_with_stats(&points, &mut NativeBackend)
        .unwrap();

    // cold populate
    let (cold, cold_stats) = Coordinator::new(opts(Some(dir.clone()), true))
        .run_sweep_with_stats(&points, &mut NativeBackend)
        .unwrap();
    assert_eq!(cold_stats.rows_from_cache, 0);
    assert_eq!(cold_stats.rows_computed, points.len());

    // fully-warm resume from a fresh coordinator (fresh in-memory state,
    // as a new process would have)
    let (warm, warm_stats) = Coordinator::new(opts(Some(dir.clone()), true))
        .run_sweep_with_stats(&points, &mut NativeBackend)
        .unwrap();

    assert_eq!(warm_stats.simulator_runs, 0, "warm resume must not simulate");
    assert_eq!(warm_stats.rows_computed, 0);
    assert_eq!(warm_stats.rows_from_cache, points.len());

    // byte-identical rows: cache write -> parse must be lossless, and the
    // cache path must not perturb the computation either
    assert_eq!(dump_rows(&plain), dump_rows(&cold));
    assert_eq!(dump_rows(&cold), dump_rows(&warm));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn superset_resume_computes_only_the_delta() {
    let dir = tmp_dir("superset");
    std::fs::remove_dir_all(&dir).ok();

    let sram = SystemConfig::preset("c1").unwrap();
    let mut fefet = SystemConfig::preset("c1").unwrap().with_tech(Technology::FEFET);
    fefet.name = "c1-fefet".into();

    // first sweep: one point
    let first = cross(&["lcs"], &[sram.clone()], LocalityRule::AnyCache);
    let (_, s1) = Coordinator::new(opts(Some(dir.clone()), true))
        .run_sweep_with_stats(&first, &mut NativeBackend)
        .unwrap();
    assert_eq!(s1.simulator_runs, 1);

    // superset sweep: adds the FeFET variant of the *same geometry*.
    // The new design point is a result-cache miss, but tech variants
    // share the analysis key, so the artifact written by the first
    // (separate) coordinator serves it — zero new simulator invocations
    // and zero replays: only the energy fold runs.
    let superset = cross(&["lcs"], &[sram, fefet], LocalityRule::AnyCache);
    let (rows, s2) = Coordinator::new(opts(Some(dir.clone()), true))
        .run_sweep_with_stats(&superset, &mut NativeBackend)
        .unwrap();
    assert_eq!(rows.len(), 2);
    assert_eq!(s2.rows_from_cache, 1);
    assert_eq!(s2.rows_computed, 1);
    assert_eq!(s2.simulator_runs, 0, "trace must not be re-simulated");
    assert_eq!(s2.analyses_run, 0, "artifact must come from the disk store");
    assert_eq!(s2.analyses_cached, 1);
    assert_eq!(s2.replays_skipped, 1);
    assert_ne!(rows[0].tech, rows[1].tech);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn resume_off_recomputes_but_still_matches() {
    let dir = tmp_dir("noresume");
    std::fs::remove_dir_all(&dir).ok();
    let points = two_by_two_points();
    let (cold, _) = Coordinator::new(opts(Some(dir.clone()), true))
        .run_sweep_with_stats(&points, &mut NativeBackend)
        .unwrap();
    // resume off: the cache is write-only, everything recomputes
    let (recomputed, stats) = Coordinator::new(opts(Some(dir.clone()), false))
        .run_sweep_with_stats(&points, &mut NativeBackend)
        .unwrap();
    assert_eq!(stats.rows_from_cache, 0);
    assert_eq!(stats.rows_computed, points.len());
    assert_eq!(dump_rows(&cold), dump_rows(&recomputed));
    std::fs::remove_dir_all(&dir).ok();
}

/// Generate a pseudo-random but *valid* design point from a seeded Rng.
fn random_point(rng: &mut Rng) -> (SweepPoint, SweepOptions) {
    let preset = *rng.choice(&["c1", "c2", "c3", "spm1mb"]);
    let mut cfg = SystemConfig::preset(preset).unwrap();
    if rng.gen_bool(0.5) {
        cfg.tech = Technology::FEFET;
    }
    cfg.cim_levels = *rng.choice(&[
        CimLevels::None,
        CimLevels::L1Only,
        CimLevels::L2Only,
        CimLevels::Both,
    ]);
    cfg.l1d.capacity <<= rng.gen_range(2) as u32;
    let bench = rng.choice(&eva_cim::workloads::NAMES).to_string();
    let rule = *rng.choice(&[
        LocalityRule::AnyCache,
        LocalityRule::SameLevel,
        LocalityRule::SameBank,
    ]);
    let opts = SweepOptions {
        scale: rng.range(1, 16),
        seed: rng.next_u64() % 1000,
        ..Default::default()
    };
    (SweepPoint { bench, config: cfg, rule }, opts)
}

#[test]
fn cache_key_is_stable_for_a_fixed_seed_and_sensitive_to_content() {
    check(
        "point-key-stable",
        60,
        |rng, _size| random_point(rng),
        |(p, o)| {
            let k1 = key::point_key(p, o, "native");
            // recompute from deep clones: the key is a pure function of
            // content, not of allocation or iteration order
            let p2 = SweepPoint {
                bench: p.bench.clone(),
                config: p.config.clone(),
                rule: p.rule,
            };
            let k2 = key::point_key(&p2, &o.clone(), "native");
            if k1 != k2 {
                return Err(format!("key not deterministic: {k1} vs {k2}"));
            }
            if k1.len() != 16 || !k1.bytes().all(|b| b.is_ascii_hexdigit()) {
                return Err(format!("malformed key '{k1}'"));
            }
            // content sensitivity: seed, geometry and backend all matter
            let mut o2 = o.clone();
            o2.seed += 1;
            if key::point_key(p, &o2, "native") == k1 {
                return Err("seed change did not change key".into());
            }
            let mut p3 = p2;
            p3.config.l2.capacity *= 2;
            if key::point_key(&p3, o, "native") == k1 {
                return Err("geometry change did not change key".into());
            }
            if key::point_key(p, o, "pjrt") == k1 {
                return Err("backend change did not change key".into());
            }
            Ok(())
        },
    );
}

#[test]
fn pinned_key_guards_cross_run_stability() {
    // A fixed design point must hash to the same key in every build and
    // every run; if this assertion ever fires, the cache key schema
    // changed and the cache schema version must be bumped with it.
    let p = SweepPoint {
        bench: "lcs".into(),
        config: SystemConfig::preset("c1").unwrap(),
        rule: LocalityRule::AnyCache,
    };
    let o = SweepOptions { scale: 4, seed: 7, ..Default::default() };
    let k1 = key::point_key(&p, &o, "native");
    let k2 = key::point_key(&p, &o, "native");
    assert_eq!(k1, k2);
    // the key must be derived from the canonical payload, so re-building
    // the identical config from scratch yields the identical key
    let rebuilt = SweepPoint {
        bench: "lcs".into(),
        config: SystemConfig::preset("c1").unwrap(),
        rule: LocalityRule::AnyCache,
    };
    assert_eq!(key::point_key(&rebuilt, &o, "native"), k1);
}

#[test]
fn row_serialization_roundtrips_for_random_rows() {
    use eva_cim::analyzer::Macr;
    use eva_cim::profiler::ProfileResult;

    check(
        "row-roundtrip",
        40,
        |rng, _size| {
            let mut result = ProfileResult {
                total_base: rng.uniform(1.0, 1e9),
                total_cim: rng.uniform(1.0, 1e9),
                improvement: rng.uniform(0.1, 10.0),
                speedup: rng.uniform(0.1, 4.0),
                ratio_proc: rng.uniform(-1.0, 2.0),
                ratio_cache: rng.uniform(-1.0, 2.0),
                ..Default::default()
            };
            for i in 0..result.comps_base.len() {
                result.comps_base[i] = rng.uniform(0.0, 1e8);
                result.comps_cim[i] = rng.uniform(0.0, 1e8);
            }
            for i in 0..result.e_l1.len() {
                result.e_l1[i] = rng.uniform(0.0, 500.0);
                result.lat_l1[i] = rng.uniform(0.0, 20.0);
                result.e_l2[i] = rng.uniform(0.0, 500.0);
                result.lat_l2[i] = rng.uniform(0.0, 20.0);
            }
            SweepRow {
                bench: rng.choice(&eva_cim::workloads::NAMES).to_string(),
                config_name: format!("cfg-{}", rng.gen_range(100)),
                tech: *rng.choice(&Technology::all()),
                cim_levels: *rng.choice(&[CimLevels::None, CimLevels::Both]),
                macr: Macr {
                    total_accesses: rng.next_u64() % (1 << 40),
                    convertible: rng.next_u64() % (1 << 40),
                    convertible_l1: rng.next_u64() % (1 << 40),
                    convertible_other: rng.next_u64() % (1 << 40),
                    cim_ops: rng.next_u64() % (1 << 40),
                },
                committed: rng.next_u64() % (1 << 50),
                cycles: rng.next_u64() % (1 << 50),
                removed: rng.next_u64() % (1 << 40),
                cim_ops: rng.next_u64() % (1 << 40),
                result,
            }
        },
        |row| {
            let dumped = persist::row_to_json(row).dump();
            let parsed = eva_cim::util::json::parse(&dumped)
                .map_err(|e| format!("reparse failed: {e}"))?;
            let row2 = persist::row_from_json(&parsed)?;
            let redumped = persist::row_to_json(&row2).dump();
            if redumped != dumped {
                return Err(format!(
                    "roundtrip not byte-identical:\n{dumped}\nvs\n{redumped}"
                ));
            }
            Ok(())
        },
    );
}
