//! Replay-parallelism contracts (PR 7: multi-lane chunk decode +
//! worker-split analyzer fan-out).
//!
//! 1. **Lane-count invariance** — replaying a spilled trace through the
//!    analyzer fan-out at 1, 2 and 8 decode lanes produces analysis
//!    artifacts byte-identical to each other *and* to the per-record
//!    reference decoder, across several randomized workloads.
//! 2. **Report invariance** — a cached sweep forced onto the warm-replay
//!    path renders byte-identical `Report` JSON at every
//!    `replay_threads` setting (and identical to its own cold pass).
//! 3. **Corruption robustness** — truncated chunks, corrupted count /
//!    byte-length framing words, bad magic and trailing garbage are
//!    decode errors and replay misses at any lane count — never panics,
//!    never silently-wrong data.

use std::path::PathBuf;

use eva_cim::analyzer::{LocalityRule, OnlineAnalyzer};
use eva_cim::api::{BackendSel, Evaluation};
use eva_cim::config::{CimLevels, SystemConfig};
use eva_cim::coordinator::analysis_store::{artifact_to_json, AnalysisArtifact};
use eva_cim::coordinator::trace_store::{decode, encode, TraceStore};
use eva_cim::pipeline::AnalyzerFanout;
use eva_cim::probes::CollectSink;
use eva_cim::reshape::DeltaSink;
use eva_cim::sim::{simulate, Limits};
use eva_cim::workloads;

const PLACEMENTS: [CimLevels; 3] =
    [CimLevels::L1Only, CimLevels::L2Only, CimLevels::Both];

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir()
        .join(format!("eva-cim-replay-par-{tag}-{}", std::process::id()))
}

/// A three-lane fan-out (one analyzer per CiM placement) — the same
/// shape the coordinator replays into.
fn fanout() -> AnalyzerFanout<DeltaSink> {
    AnalyzerFanout::new(
        PLACEMENTS
            .iter()
            .map(|&cim| {
                OnlineAnalyzer::new(
                    cim,
                    LocalityRule::AnyCache,
                    DeltaSink::default(),
                )
            })
            .collect(),
    )
}

#[test]
fn lane_count_never_changes_the_artifacts() {
    let dir = tmp("lanes");
    std::fs::remove_dir_all(&dir).ok();
    let store = TraceStore::open(&dir).unwrap();
    let cfg = SystemConfig::preset("c1").unwrap();
    for (i, (bench, scale, seed)) in
        [("lcs", 2, 7), ("km", 2, 11), ("bfs", 3, 5)].into_iter().enumerate()
    {
        let prog = workloads::build(bench, scale, seed).unwrap();
        let trace = simulate(&prog, &cfg, Limits::default()).unwrap();
        let key = format!("t{i}");
        store.store(&key, &trace).unwrap();

        // lanes == 0 selects the per-record reference decoder
        let mut renders: Vec<Vec<String>> = Vec::new();
        for lanes in [0usize, 1, 2, 8] {
            let mut f = fanout();
            let summary = if lanes == 0 {
                store.replay_reference(&key, &mut f).unwrap()
            } else {
                let (s, chunks) =
                    store.replay_with(&key, &mut f, lanes).unwrap();
                assert!(chunks >= 1, "{bench}: no chunks decoded");
                s
            };
            assert_eq!(summary.committed, trace.committed);
            let arts: Vec<String> = f
                .finish()
                .into_iter()
                .map(|(outcome, deltas)| {
                    let a =
                        AnalysisArtifact::new(summary.clone(), outcome, deltas);
                    artifact_to_json(&a).dump()
                })
                .collect();
            assert_eq!(arts.len(), PLACEMENTS.len());
            renders.push(arts);
        }
        for r in &renders[1..] {
            assert_eq!(
                r, &renders[0],
                "{bench}: lane count changed artifact bytes"
            );
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn replay_threads_never_change_the_report() {
    let mut renders: Vec<String> = Vec::new();
    for threads in [1usize, 2, 8] {
        let dir = tmp(&format!("report-{threads}"));
        std::fs::remove_dir_all(&dir).ok();
        let ev = Evaluation::new()
            .bench("lcs")
            .preset("c1")
            .cim_variants(&PLACEMENTS)
            .scale(2)
            .jobs(4)
            .replay_threads(threads)
            .backend(BackendSel::Native)
            .cache_dir(dir.clone())
            .resume(true);

        // cold pass: simulate + spill the trace
        let cold = ev.run().unwrap().render_json();

        // strip everything except traces/, so the warm pass is forced
        // onto the replay path (split fan-out + multi-lane decode)
        std::fs::remove_file(dir.join("results.jsonl"))
            .expect("cached run must publish results.jsonl");
        std::fs::remove_dir_all(dir.join("analysis"))
            .expect("cached run must publish analysis/");
        let warm = ev.run().unwrap().render_json();

        assert_eq!(cold, warm, "warm replay changed the report bytes");
        renders.push(cold);
        std::fs::remove_dir_all(&dir).ok();
    }
    for r in &renders[1..] {
        assert_eq!(r, &renders[0], "replay_threads changed the report bytes");
    }
}

#[test]
fn corrupted_spills_are_misses_not_panics() {
    let cfg = SystemConfig::preset("c1").unwrap();
    let prog = workloads::build("lcs", 2, 7).unwrap();
    let trace = simulate(&prog, &cfg, Limits::default()).unwrap();
    let bytes = encode(&trace);
    assert!(decode(&bytes).is_ok(), "pristine bytes must decode");

    // layout: magic + version (8 bytes), then the first chunk's record
    // count at [8..12] and body byte length at [12..16]
    let count = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
    let nbytes = u32::from_le_bytes(bytes[12..16].try_into().unwrap());
    let patched = |at: usize, word: u32| {
        let mut b = bytes.clone();
        b[at..at + 4].copy_from_slice(&word.to_le_bytes());
        b
    };
    let mut truncated = bytes.clone();
    truncated.truncate(bytes.len() / 2);
    let mut garbage = bytes.clone();
    garbage.extend_from_slice(b"xx");
    let cases: Vec<(&str, Vec<u8>)> = vec![
        ("truncated mid-chunk", truncated),
        ("insane record count", patched(8, u32::MAX)),
        ("record count off by one", patched(8, count + 1)),
        ("insane chunk length", patched(12, 1 << 25)),
        ("chunk length short by one", patched(12, nbytes - 1)),
        ("chunk length long by one", patched(12, nbytes + 1)),
        ("wrong magic", patched(0, 0xdead_beef)),
        ("trailing garbage", garbage),
        ("empty file", Vec::new()),
    ];

    let dir = tmp("fuzz");
    std::fs::remove_dir_all(&dir).ok();
    let store = TraceStore::open(&dir).unwrap();
    store.store("good", &trace).unwrap();
    assert!(store.contains("good"));
    for (what, bad) in cases {
        assert!(decode(&bad).is_err(), "{what}: decode must error");
        // plant the corrupt bytes as a published spill: every replay
        // flavor must treat it as a miss
        std::fs::write(dir.join("trace-bad.bin"), &bad).unwrap();
        assert!(store.contains("bad"));
        for lanes in [1usize, 8] {
            let mut sink = CollectSink::default();
            assert!(
                store.replay_with("bad", &mut sink, lanes).is_none(),
                "{what}: replay at {lanes} lanes must miss"
            );
        }
        let mut sink = CollectSink::default();
        assert!(
            store.replay_reference("bad", &mut sink).is_none(),
            "{what}: reference replay must miss"
        );
    }

    // the good spill is untouched by its corrupt neighbor
    let mut sink = CollectSink::default();
    let summary = store.replay("good", &mut sink).unwrap();
    assert_eq!(summary.committed, trace.committed);
    assert_eq!(sink.ciq.len() as u64, trace.committed);
    std::fs::remove_dir_all(&dir).ok();
}
