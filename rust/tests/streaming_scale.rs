//! Scale contract of the streaming pipeline: analysis memory is bounded
//! by the live dependency state, not the trace length, so instruction
//! counts whose materialized CIQ (~136 B/instruction plus the IDG forest
//! and RUT on top) would blow a bounded-memory budget stream through in
//! O(window).

use eva_cim::analyzer::LocalityRule;
use eva_cim::asm::Asm;
use eva_cim::config::SystemConfig;
use eva_cim::pipeline::run_pipelined;
use eva_cim::probes::StopReason;
use eva_cim::reshape::{reshape_from_deltas, DeltaSink};
use eva_cim::sim::Limits;

/// A tight convertible loop whose counter lives in memory: every register
/// is rewritten each of the 10 body instructions, so the trace length is
/// unbounded while the live analysis window is a handful of instructions.
fn loop_program(iters: i32) -> eva_cim::asm::Program {
    let mut a = Asm::new("stream-scale");
    let buf = a.data.alloc_i32("buf", &[7, 9, 0, 0, 0, 0, 0, 0]);
    a.li(1, buf as i32);
    a.li(9, buf as i32 + 16); // counter cell
    let top = a.label("top");
    a.bind(top);
    a.lw(2, 1, 0);
    a.lw(3, 1, 4);
    a.add(4, 2, 3);
    a.sw(4, 1, 8);
    a.lw(7, 9, 0);
    a.addi(7, 7, 1);
    a.sw(7, 9, 0);
    a.li(8, iters);
    a.bne(7, 8, top);
    a.halt();
    a.assemble()
}

#[test]
fn long_trace_streams_with_bounded_window() {
    // ~270k committed instructions; the batch path would materialize a
    // ~37 MB CIQ plus forest/RUT overhead *per sweep worker* — the
    // streaming live set must stay O(loop body) instead.
    let cfg = SystemConfig::preset("c1").unwrap();
    let prog = loop_program(30_000);
    let (summary, outcome, deltas) = run_pipelined(
        &prog,
        &cfg,
        Limits::default(),
        LocalityRule::AnyCache,
        DeltaSink::default(),
        None,
    )
    .unwrap();
    assert_eq!(summary.stop, StopReason::Halt);
    assert!(summary.committed > 260_000, "committed {}", summary.committed);
    assert!(
        outcome.peak_window < 128,
        "window {} must not scale with the {}-instruction trace",
        outcome.peak_window,
        summary.committed
    );
    // the analysis actually did its job at scale
    assert!(outcome.candidates > 25_000, "candidates {}", outcome.candidates);
    let reshaped = reshape_from_deltas(&summary, &deltas, &cfg);
    assert!(reshaped.removed > 50_000, "removed {}", reshaped.removed);
    assert!(outcome.macr.ratio() > 0.3, "macr {}", outcome.macr.ratio());
}

#[test]
fn max_instructions_cap_streams_cleanly() {
    // an effectively-infinite loop capped by Limits: the stream ends with
    // MaxInstructions and all pending window state retires at finish
    let cfg = SystemConfig::preset("c1").unwrap();
    let prog = loop_program(i32::MAX);
    let (summary, outcome, _) = run_pipelined(
        &prog,
        &cfg,
        Limits { max_instructions: 80_000 },
        LocalityRule::AnyCache,
        DeltaSink::default(),
        None,
    )
    .unwrap();
    assert_eq!(summary.stop, StopReason::MaxInstructions);
    assert_eq!(summary.committed, 80_000);
    assert!(outcome.peak_window < 128, "window {}", outcome.peak_window);
}
