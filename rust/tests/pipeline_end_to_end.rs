//! End-to-end integration: workload → simulate → analyze → reshape →
//! profile (native backend) for every benchmark in Table IV.

use eva_cim::analyzer::{analyze, LocalityRule};
use eva_cim::config::{CimLevels, SystemConfig, Technology};
use eva_cim::profiler::{evaluate_native, ProfileInputs, ProfileResult};
use eva_cim::probes::{StopReason, Trace};
use eva_cim::reshape::reshape;
use eva_cim::sim::{simulate, Limits};
use eva_cim::workloads;

fn pipeline(bench: &str, cfg: &SystemConfig) -> (Trace, ProfileResult) {
    let prog = workloads::build(bench, 2, 7).expect(bench);
    let trace = simulate(&prog, cfg, Limits::default()).expect(bench);
    let analysis = analyze(&trace, cfg, LocalityRule::AnyCache);
    let reshaped = reshape(&trace, &analysis.selection, cfg);
    let res = evaluate_native(&ProfileInputs::new(cfg, &reshaped));
    (trace, res)
}

#[test]
fn every_benchmark_profiles_end_to_end() {
    let cfg = SystemConfig::preset("c1").unwrap();
    for bench in workloads::NAMES {
        let (trace, res) = pipeline(bench, &cfg);
        assert_eq!(trace.stop, StopReason::Halt, "{bench}");
        assert!(res.total_base > 0.0, "{bench}");
        assert!(res.total_cim > 0.0, "{bench}");
        assert!(
            res.improvement >= 0.99,
            "{bench}: CiM made energy worse ({})",
            res.improvement
        );
        assert!(
            res.speedup > 0.5 && res.speedup < 3.0,
            "{bench}: implausible speedup {}",
            res.speedup
        );
        let ratios_ok = (res.ratio_proc + res.ratio_cache - 1.0).abs() < 1e-6
            || (res.ratio_proc == 0.0 && res.ratio_cache == 0.0);
        assert!(ratios_ok, "{bench}: ratios {} {}", res.ratio_proc, res.ratio_cache);
    }
}

#[test]
fn cim_none_is_identity() {
    let cfg = SystemConfig::preset("c1").unwrap().with_cim(CimLevels::None);
    let (_, res) = pipeline("lcs", &cfg);
    assert!((res.improvement - 1.0).abs() < 1e-9);
    assert!((res.speedup - 1.0).abs() < 1e-9);
}

#[test]
fn fefet_beats_sram_on_energy_for_cim_friendly_bench() {
    let sram = SystemConfig::preset("c1").unwrap().with_tech(Technology::SRAM);
    let fefet = SystemConfig::preset("c1").unwrap().with_tech(Technology::FEFET);
    let (_, rs) = pipeline("m2d", &sram);
    let (_, rf) = pipeline("m2d", &fefet);
    // Fig 16: FeFET CiM energy normalized against the SRAM baseline
    let fefet_norm = rs.total_base / rf.total_cim.max(1e-9);
    assert!(
        fefet_norm > rs.improvement,
        "FeFET {fefet_norm} !> SRAM {}",
        rs.improvement
    );
}

#[test]
fn larger_l2_raises_per_op_energy() {
    // finding (iii): larger memories pay more per CiM operation
    let c1 = SystemConfig::preset("c1").unwrap();
    let c3 = SystemConfig::preset("c3").unwrap();
    let (_, r1) = pipeline("sssp", &c1);
    let (_, r3) = pipeline("sssp", &c3);
    assert!(
        r3.e_l2[eva_cim::energy::calib::OP_ADD] > r1.e_l2[eva_cim::energy::calib::OP_ADD]
    );
}

#[test]
fn stricter_locality_rules_select_fewer() {
    let cfg = SystemConfig::preset("c1").unwrap();
    let prog = workloads::build("lcs", 2, 7).unwrap();
    let trace = simulate(&prog, &cfg, Limits::default()).unwrap();
    let any = analyze(&trace, &cfg, LocalityRule::AnyCache);
    let level = analyze(&trace, &cfg, LocalityRule::SameLevel);
    let bank = analyze(&trace, &cfg, LocalityRule::SameBank);
    assert!(level.macr.convertible <= any.macr.convertible);
    assert!(bank.macr.convertible <= level.macr.convertible);
}

#[test]
fn high_macr_benches_beat_low_macr_benches() {
    // finding (ii) in reverse: CiM-favorable programs earn more energy
    // improvement than CiM-unfavorable ones
    let cfg = SystemConfig::preset("c1").unwrap();
    let (_, m2d) = pipeline("m2d", &cfg);
    let (_, lir) = pipeline("lir", &cfg);
    let (_, dfs) = pipeline("dfs", &cfg);
    assert!(m2d.improvement > lir.improvement);
    assert!(m2d.improvement > dfs.improvement);
}

#[test]
fn deterministic_pipeline() {
    let cfg = SystemConfig::preset("c1").unwrap();
    let a = pipeline("nb", &cfg).1;
    let b = pipeline("nb", &cfg).1;
    assert_eq!(a.total_base, b.total_base);
    assert_eq!(a.improvement, b.improvement);
}
