//! Property-based tests over the simulator: functional correctness against
//! a plain Rust interpreter-free oracle, timing sanity, cache invariants,
//! and front-end micro-properties (branch-predictor redirect bubbles,
//! store-buffer completion, fetch-line refetch after redirects) replayed
//! against shadow oracles driven by the committed-instruction queue.

use eva_cim::asm::Asm;
use eva_cim::config::SystemConfig;
use eva_cim::isa::Opcode;
use eva_cim::probes::Trace;
use eva_cim::sim::bpred::BranchPredictor;
use eva_cim::sim::{simulate, Limits};
use eva_cim::util::proptest::check;
use eva_cim::util::Rng;

/// Random arithmetic expression over loaded values; returns (program,
/// expected final store value).  The oracle mirrors the arithmetic in Rust,
/// and the program self-checks: three marker `nop`s execute only on a
/// mismatch between the simulated and expected value.
fn random_arith(rng: &mut Rng, size: u32) -> (eva_cim::asm::Program, i32) {
    let n = 4 + (size as usize % 12);
    let vals: Vec<i32> = (0..n).map(|_| rng.gen_range(1000) as i32 - 500).collect();
    let mut a = Asm::new("prop-arith");
    let buf = a.data.alloc_i32("buf", &vals);
    let out = a.data.alloc_i32("out", &[0]);
    a.li(1, buf as i32);
    a.lw(2, 1, 0);
    let mut acc = vals[0];
    for (i, v) in vals.iter().enumerate().skip(1) {
        a.lw(3, 1, (i * 4) as i32);
        match rng.gen_range(5) {
            0 => {
                a.add(2, 2, 3);
                acc = acc.wrapping_add(*v);
            }
            1 => {
                a.sub(2, 2, 3);
                acc = acc.wrapping_sub(*v);
            }
            2 => {
                a.xor(2, 2, 3);
                acc ^= *v;
            }
            3 => {
                a.and(2, 2, 3);
                acc &= *v;
            }
            _ => {
                a.mul(2, 2, 3);
                acc = acc.wrapping_mul(*v);
            }
        }
    }
    a.li(4, out as i32);
    a.sw(2, 4, 0);
    // reload and self-check: branch to a dead halt if mismatch
    a.lw(5, 4, 0);
    a.li(6, acc);
    let ok = a.label("ok");
    a.beq(5, 6, ok);
    a.nop(); // mismatch marker: falls through to halt too, detected by test
    a.nop();
    a.nop();
    a.bind(ok);
    a.halt();
    (a.assemble(), acc)
}

#[test]
fn prop_functional_arithmetic_matches_oracle() {
    check(
        "functional-arith",
        80,
        |rng, size| random_arith(rng, size),
        |(prog, _acc)| {
            let cfg = SystemConfig::preset("c1").unwrap();
            let t = simulate(prog, &cfg, Limits::default()).unwrap();
            // the self-check branch skips the 3 nops iff the value matched
            let nops = t
                .ciq
                .iter()
                .filter(|i| i.instr.op == eva_cim::isa::Opcode::Nop)
                .count();
            if nops != 0 {
                return Err("self-check nops executed: wrong arithmetic".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_timing_monotone_and_cpi_bounded() {
    check(
        "timing-sane",
        60,
        |rng, size| {
            let n = 8 + (size as usize % 40);
            let vals: Vec<i32> = (0..n).map(|_| rng.gen_range(100) as i32).collect();
            let mut a = Asm::new("t");
            let buf = a.data.alloc_i32("buf", &vals);
            a.li(1, buf as i32);
            for i in 0..n {
                a.lw(2, 1, ((i % n) * 4) as i32);
                a.add(3, 3, 2);
            }
            a.halt();
            let cfg = SystemConfig::preset("c1").unwrap();
            simulate(&a.assemble(), &cfg, Limits::default()).unwrap()
        },
        |t| {
            if t.cycles == 0 {
                return Err("zero cycles".into());
            }
            let cpi = t.cpi();
            if !(0.3..=80.0).contains(&cpi) {
                return Err(format!("implausible CPI {cpi}"));
            }
            // commit ticks monotone
            for w in t.ciq.windows(2) {
                if w[0].tick_commit > w[1].tick_commit {
                    return Err("commit order violated".into());
                }
            }
            // stage ordering per instruction
            for i in &t.ciq {
                if !(i.tick_fetch <= i.tick_decode
                    && i.tick_decode <= i.tick_rename
                    && i.tick_rename <= i.tick_dispatch
                    && i.tick_dispatch <= i.tick_issue
                    && i.tick_issue <= i.tick_complete
                    && i.tick_complete < i.tick_commit)
                {
                    return Err(format!("stage order broken at seq {}", i.seq));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cache_stats_consistent_with_accesses() {
    check(
        "cache-stats-consistent",
        60,
        |rng, size| {
            let n = 16 + (size as usize % 64);
            let mut a = Asm::new("t");
            let buf = a.data.alloc_i32("buf", &vec![7i32; n.max(16)]);
            a.li(1, buf as i32);
            for _ in 0..n {
                let off = (rng.gen_range(n as u64) as i32) * 4;
                if rng.gen_bool(0.3) {
                    a.sw(2, 1, off % 256);
                } else {
                    a.lw(2, 1, off % 256);
                }
            }
            a.halt();
            let cfg = SystemConfig::preset("c1").unwrap();
            simulate(&a.assemble(), &cfg, Limits::default()).unwrap()
        },
        |t| {
            let m = &t.mem;
            let data_reads = m.l1d_read_hits + m.l1d_read_misses;
            let data_writes = m.l1d_write_hits + m.l1d_write_misses;
            if data_reads != t.pipe.lsq_reads {
                return Err(format!(
                    "reads {} != lsq {}",
                    data_reads, t.pipe.lsq_reads
                ));
            }
            if data_writes != t.pipe.lsq_writes {
                return Err("writes != lsq writes".into());
            }
            // every CIQ mem record must agree with hit flags
            for i in &t.ciq {
                if let Some(mem) = i.mem {
                    if mem.l1_hit && mem.level != eva_cim::probes::MemLevel::L1 {
                        return Err("l1_hit but level != L1".into());
                    }
                }
            }
            Ok(())
        },
    );
}

/// Branch-heavy random program: forward branches over 1–3 fillers (the
/// filler count keeps taken/not-taken distinguishable from the commit
/// stream — a taken branch to `pc + 1` would be ambiguous), short
/// backward loops with memory traffic, and jal/jalr redirects.  Always
/// commits a plain `addi` after the last branch so every committed cond
/// branch has a successor record.
fn branchy_trace(rng: &mut Rng, size: u32) -> Trace {
    let mut a = Asm::new("branchy");
    let vals: Vec<i32> = (0..16).map(|_| rng.gen_range(100) as i32 - 50).collect();
    let buf = a.data.alloc_i32("buf", &vals);
    a.li(1, buf as i32);
    a.lw(3, 1, 0);
    a.lw(4, 1, 4);
    let n = 6 + (size as usize % 24);
    for _ in 0..n {
        match rng.gen_range(4) {
            0 | 1 => {
                // data-dependent forward branch over 1..=3 fillers
                let l = a.label("fwd");
                match rng.gen_range(3) {
                    0 => {
                        a.beq(3, 4, l);
                    }
                    1 => {
                        a.blt(3, 4, l);
                    }
                    _ => {
                        a.bne(3, 4, l);
                    }
                }
                for _ in 0..(1 + rng.gen_range(3)) {
                    a.addi(3, 3, 1);
                }
                a.bind(l);
            }
            2 => {
                // short backward loop: warms the predictor, mispredicts at
                // exit, and mixes I-fetch with D-cache traffic
                let top = a.label("top");
                a.li(5, 0);
                a.li(6, 2 + rng.gen_range(12) as i32);
                a.bind(top);
                a.addi(5, 5, 1);
                a.lw(4, 1, (rng.gen_range(16) as i32) * 4);
                a.bne(5, 6, top);
            }
            _ if rng.gen_bool(0.5) => {
                // jal always redirects the fetch line
                let l = a.label("j");
                a.jal(7, l);
                a.nop(); // skipped
                a.bind(l);
            }
            _ => {
                // jalr with a data-dependent target (li, jalr, dead nop)
                let t = a.len() as i32 + 3;
                a.li(8, t);
                a.jalr(9, 8);
                a.nop(); // skipped
            }
        }
    }
    a.addi(3, 3, 0); // successor for the last branch
    a.halt();
    let cfg = SystemConfig::preset("c1").unwrap();
    simulate(&a.assemble(), &cfg, Limits::default()).unwrap()
}

/// Replay the commit stream through a shadow `BranchPredictor` (same
/// construction as the simulator's) and check (a) the pipeline's lookup /
/// mispredict counters match the oracle exactly, (b) every mispredicted
/// branch is followed by the full `mispredict_penalty` refetch bubble,
/// and (c) a *correctly* predicted taken branch still pays the 2-cycle
/// BTB redirect bubble.
#[test]
fn prop_bpred_redirect_and_mispredict_bubbles() {
    check(
        "bpred-redirect-bubble",
        40,
        branchy_trace,
        |t| {
            let cfg = SystemConfig::preset("c1").unwrap();
            let mut oracle = BranchPredictor::new(12);
            let mut lookups = 0u64;
            let mut mispredicts = 0u64;
            for w in t.ciq.windows(2) {
                let (b, next) = (&w[0], &w[1]);
                if !b.instr.op.is_cond_branch() {
                    continue;
                }
                lookups += 1;
                let taken = next.pc != b.pc + 1;
                let pred = oracle.predict(b.pc);
                if oracle.update(b.pc, taken, b.instr.imm as u32, pred) {
                    mispredicts += 1;
                    let bubble = b.tick_complete + cfg.core.mispredict_penalty;
                    if next.tick_fetch < bubble {
                        return Err(format!(
                            "seq {}: mispredict refetch at {} before \
                             complete {} + penalty {}",
                            b.seq,
                            next.tick_fetch,
                            b.tick_complete,
                            cfg.core.mispredict_penalty
                        ));
                    }
                } else if taken && next.tick_fetch < b.tick_fetch + 2 {
                    return Err(format!(
                        "seq {}: correct-taken branch skipped the BTB \
                         redirect bubble ({} < {} + 2)",
                        b.seq, next.tick_fetch, b.tick_fetch
                    ));
                }
            }
            if t.pipe.bpred_lookups != lookups {
                return Err(format!(
                    "bpred_lookups {} != committed cond branches {}",
                    t.pipe.bpred_lookups, lookups
                ));
            }
            if t.pipe.bpred_mispredicts != mispredicts {
                return Err(format!(
                    "bpred_mispredicts {} != shadow predictor {}",
                    t.pipe.bpred_mispredicts, mispredicts
                ));
            }
            Ok(())
        },
    );
}

/// Stores drain through the store buffer in exactly one cycle
/// (`tick_complete == tick_issue + 1`), while loads pay at least the L1D
/// hit latency — the asymmetry that makes store-heavy code cheap in the
/// timing model.
#[test]
fn prop_store_buffer_single_cycle_completion() {
    check(
        "store-buffer-1cy",
        40,
        |rng, size| {
            let n = 12 + (size as usize % 48);
            let mut a = Asm::new("stores");
            let buf = a.data.alloc_i32("buf", &vec![3i32; 32]);
            a.li(1, buf as i32);
            a.lw(2, 1, 0);
            for _ in 0..n {
                let off = (rng.gen_range(32) as i32) * 4;
                match rng.gen_range(4) {
                    0 => {
                        a.lw(2, 1, off);
                    }
                    1 => {
                        a.sb(2, 1, rng.gen_range(128) as i32);
                    }
                    _ => {
                        a.sw(2, 1, off);
                    }
                }
            }
            a.halt();
            let cfg = SystemConfig::preset("c1").unwrap();
            simulate(&a.assemble(), &cfg, Limits::default()).unwrap()
        },
        |t| {
            let cfg = SystemConfig::preset("c1").unwrap();
            let mut stores = 0u64;
            for i in &t.ciq {
                if i.instr.op.is_store() {
                    stores += 1;
                    if i.tick_complete != i.tick_issue + 1 {
                        return Err(format!(
                            "seq {}: store completed at {} not issue {} + 1",
                            i.seq, i.tick_complete, i.tick_issue
                        ));
                    }
                } else if i.instr.op.is_load()
                    && i.tick_complete < i.tick_issue + cfg.l1d.latency
                {
                    return Err(format!(
                        "seq {}: load beat the L1D hit latency",
                        i.seq
                    ));
                }
            }
            if stores == 0 {
                return Err("generator produced no stores".into());
            }
            Ok(())
        },
    );
}

/// The front end fetches one I-cache line per 8 sequential instructions
/// and refetches after every redirect (mispredicted cond branch, jal,
/// jalr).  Replaying that automaton over the commit stream must land
/// exactly on the L1I access count the memory hierarchy recorded.
#[test]
fn prop_fetch_line_refetch_after_redirect() {
    check(
        "fetch-line-refetch",
        40,
        branchy_trace,
        |t| {
            let mut oracle = BranchPredictor::new(12);
            let mut last_line = u32::MAX;
            let mut accesses = 0u64;
            for (k, i) in t.ciq.iter().enumerate() {
                let line = i.pc / 8;
                if line != last_line {
                    accesses += 1;
                    last_line = line;
                }
                if i.instr.op.is_cond_branch() {
                    let taken = match t.ciq.get(k + 1) {
                        Some(next) => next.pc != i.pc + 1,
                        None => false,
                    };
                    let pred = oracle.predict(i.pc);
                    if oracle.update(i.pc, taken, i.instr.imm as u32, pred) {
                        last_line = u32::MAX; // redirect refetches the line
                    }
                } else if matches!(i.instr.op, Opcode::Jal | Opcode::Jalr) {
                    last_line = u32::MAX;
                }
            }
            let l1i = t.mem.l1i_hits + t.mem.l1i_misses;
            if l1i != accesses {
                return Err(format!(
                    "L1I accesses {l1i} != front-end line fetches {accesses}"
                ));
            }
            Ok(())
        },
    );
}
