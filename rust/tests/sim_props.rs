//! Property-based tests over the simulator: functional correctness against
//! a plain Rust interpreter-free oracle, timing sanity, cache invariants.

use eva_cim::asm::Asm;
use eva_cim::config::SystemConfig;
use eva_cim::sim::{simulate, Limits};
use eva_cim::util::proptest::check;
use eva_cim::util::Rng;

/// Random arithmetic expression over loaded values; returns (program,
/// expected final store value).  The oracle mirrors the arithmetic in Rust,
/// and the program self-checks: three marker `nop`s execute only on a
/// mismatch between the simulated and expected value.
fn random_arith(rng: &mut Rng, size: u32) -> (eva_cim::asm::Program, i32) {
    let n = 4 + (size as usize % 12);
    let vals: Vec<i32> = (0..n).map(|_| rng.gen_range(1000) as i32 - 500).collect();
    let mut a = Asm::new("prop-arith");
    let buf = a.data.alloc_i32("buf", &vals);
    let out = a.data.alloc_i32("out", &[0]);
    a.li(1, buf as i32);
    a.lw(2, 1, 0);
    let mut acc = vals[0];
    for (i, v) in vals.iter().enumerate().skip(1) {
        a.lw(3, 1, (i * 4) as i32);
        match rng.gen_range(5) {
            0 => {
                a.add(2, 2, 3);
                acc = acc.wrapping_add(*v);
            }
            1 => {
                a.sub(2, 2, 3);
                acc = acc.wrapping_sub(*v);
            }
            2 => {
                a.xor(2, 2, 3);
                acc ^= *v;
            }
            3 => {
                a.and(2, 2, 3);
                acc &= *v;
            }
            _ => {
                a.mul(2, 2, 3);
                acc = acc.wrapping_mul(*v);
            }
        }
    }
    a.li(4, out as i32);
    a.sw(2, 4, 0);
    // reload and self-check: branch to a dead halt if mismatch
    a.lw(5, 4, 0);
    a.li(6, acc);
    let ok = a.label("ok");
    a.beq(5, 6, ok);
    a.nop(); // mismatch marker: falls through to halt too, detected by test
    a.nop();
    a.nop();
    a.bind(ok);
    a.halt();
    (a.assemble(), acc)
}

#[test]
fn prop_functional_arithmetic_matches_oracle() {
    check(
        "functional-arith",
        80,
        |rng, size| random_arith(rng, size),
        |(prog, _acc)| {
            let cfg = SystemConfig::preset("c1").unwrap();
            let t = simulate(prog, &cfg, Limits::default()).unwrap();
            // the self-check branch skips the 3 nops iff the value matched
            let nops = t
                .ciq
                .iter()
                .filter(|i| i.instr.op == eva_cim::isa::Opcode::Nop)
                .count();
            if nops != 0 {
                return Err("self-check nops executed: wrong arithmetic".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_timing_monotone_and_cpi_bounded() {
    check(
        "timing-sane",
        60,
        |rng, size| {
            let n = 8 + (size as usize % 40);
            let vals: Vec<i32> = (0..n).map(|_| rng.gen_range(100) as i32).collect();
            let mut a = Asm::new("t");
            let buf = a.data.alloc_i32("buf", &vals);
            a.li(1, buf as i32);
            for i in 0..n {
                a.lw(2, 1, ((i % n) * 4) as i32);
                a.add(3, 3, 2);
            }
            a.halt();
            let cfg = SystemConfig::preset("c1").unwrap();
            simulate(&a.assemble(), &cfg, Limits::default()).unwrap()
        },
        |t| {
            if t.cycles == 0 {
                return Err("zero cycles".into());
            }
            let cpi = t.cpi();
            if !(0.3..=80.0).contains(&cpi) {
                return Err(format!("implausible CPI {cpi}"));
            }
            // commit ticks monotone
            for w in t.ciq.windows(2) {
                if w[0].tick_commit > w[1].tick_commit {
                    return Err("commit order violated".into());
                }
            }
            // stage ordering per instruction
            for i in &t.ciq {
                if !(i.tick_fetch <= i.tick_decode
                    && i.tick_decode <= i.tick_rename
                    && i.tick_rename <= i.tick_dispatch
                    && i.tick_dispatch <= i.tick_issue
                    && i.tick_issue <= i.tick_complete
                    && i.tick_complete < i.tick_commit)
                {
                    return Err(format!("stage order broken at seq {}", i.seq));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cache_stats_consistent_with_accesses() {
    check(
        "cache-stats-consistent",
        60,
        |rng, size| {
            let n = 16 + (size as usize % 64);
            let mut a = Asm::new("t");
            let buf = a.data.alloc_i32("buf", &vec![7i32; n.max(16)]);
            a.li(1, buf as i32);
            for _ in 0..n {
                let off = (rng.gen_range(n as u64) as i32) * 4;
                if rng.gen_bool(0.3) {
                    a.sw(2, 1, off % 256);
                } else {
                    a.lw(2, 1, off % 256);
                }
            }
            a.halt();
            let cfg = SystemConfig::preset("c1").unwrap();
            simulate(&a.assemble(), &cfg, Limits::default()).unwrap()
        },
        |t| {
            let m = &t.mem;
            let data_reads = m.l1d_read_hits + m.l1d_read_misses;
            let data_writes = m.l1d_write_hits + m.l1d_write_misses;
            if data_reads != t.pipe.lsq_reads {
                return Err(format!(
                    "reads {} != lsq {}",
                    data_reads, t.pipe.lsq_reads
                ));
            }
            if data_writes != t.pipe.lsq_writes {
                return Err("writes != lsq writes".into());
            }
            // every CIQ mem record must agree with hit flags
            for i in &t.ciq {
                if let Some(mem) = i.mem {
                    if mem.l1_hit && mem.level != eva_cim::probes::MemLevel::L1 {
                        return Err("l1_hit but level != L1".into());
                    }
                }
            }
            Ok(())
        },
    );
}
