//! Every Table IV workload: terminates, is deterministic, scales with the
//! `scale` knob, and produces analyzable traces on every preset config.

use eva_cim::analyzer::{analyze, LocalityRule};
use eva_cim::config::SystemConfig;
use eva_cim::probes::StopReason;
use eva_cim::sim::{simulate, Limits};
use eva_cim::workloads;

#[test]
fn all_workloads_halt_on_all_presets() {
    for preset in ["c1", "c2", "c3"] {
        let cfg = SystemConfig::preset(preset).unwrap();
        for bench in workloads::NAMES {
            let prog = workloads::build(bench, 1, 11).expect(bench);
            let t = simulate(&prog, &cfg, Limits::default())
                .unwrap_or_else(|e| panic!("{bench}@{preset}: {e}"));
            assert_eq!(t.stop, StopReason::Halt, "{bench}@{preset}");
            assert!(t.committed > 1000, "{bench}@{preset}: {}", t.committed);
        }
    }
}

#[test]
fn scale_increases_work() {
    for bench in ["lcs", "bfs", "nb", "mcf"] {
        let small = simulate(
            &workloads::build(bench, 1, 3).unwrap(),
            &SystemConfig::default(),
            Limits::default(),
        )
        .unwrap();
        let big = simulate(
            &workloads::build(bench, 8, 3).unwrap(),
            &SystemConfig::default(),
            Limits::default(),
        )
        .unwrap();
        assert!(
            big.committed > small.committed * 2,
            "{bench}: {} !> 2x {}",
            big.committed,
            small.committed
        );
    }
}

#[test]
fn workloads_are_deterministic() {
    for bench in workloads::NAMES {
        let cfg = SystemConfig::default();
        let a = simulate(
            &workloads::build(bench, 1, 17).unwrap(),
            &cfg,
            Limits::default(),
        )
        .unwrap();
        let b = simulate(
            &workloads::build(bench, 1, 17).unwrap(),
            &cfg,
            Limits::default(),
        )
        .unwrap();
        assert_eq!(a.committed, b.committed, "{bench}");
        assert_eq!(a.cycles, b.cycles, "{bench}");
        assert_eq!(a.mem.l1d_read_hits, b.mem.l1d_read_hits, "{bench}");
    }
}

#[test]
fn macr_spans_a_wide_range_across_workloads() {
    // finding (ii): data-intensive does not imply CiM-convertible — the
    // suite must contain both CiM-favorable and CiM-unfavorable programs
    let cfg = SystemConfig::preset("c1").unwrap();
    let mut ratios = Vec::new();
    for bench in workloads::NAMES {
        let prog = workloads::build(bench, 1, 7).unwrap();
        let t = simulate(&prog, &cfg, Limits::default()).unwrap();
        let an = analyze(&t, &cfg, LocalityRule::AnyCache);
        ratios.push((bench, an.macr.ratio()));
    }
    let hi = ratios.iter().filter(|(_, r)| *r > 0.5).count();
    let lo = ratios.iter().filter(|(_, r)| *r < 0.2).count();
    assert!(hi >= 3, "need ≥3 CiM-favorable workloads: {ratios:?}");
    assert!(lo >= 2, "need ≥2 CiM-unfavorable workloads: {ratios:?}");
}

#[test]
fn spec_kernels_have_distinct_profiles() {
    // sanity: the four SPEC kernels should not be near-identical traces
    let cfg = SystemConfig::default();
    let mut cpis = Vec::new();
    for bench in ["astar", "h264ref", "hmmer", "mcf"] {
        let t = simulate(
            &workloads::build(bench, 1, 5).unwrap(),
            &cfg,
            Limits::default(),
        )
        .unwrap();
        cpis.push(t.cpi());
    }
    let min = cpis.iter().cloned().fold(f64::MAX, f64::min);
    let max = cpis.iter().cloned().fold(0.0, f64::max);
    assert!(max / min > 1.05, "CPIs suspiciously uniform: {cpis:?}");
}
