//! The stage-factored sweep contract (simulate / analyze / energy-fold).
//!
//! Pins the three properties that make the factoring safe and worth it:
//!
//! 1. **Row equivalence** — a grouped sweep over T technologies × P
//!    placements produces rows *byte-identical* to the unfactored
//!    per-point path (one pipelined simulate+analyze per point), in the
//!    canonical row serialization and in all three report renderings
//!    (table, CSV, JSON).
//! 2. **Work collapse** — the same sweep runs exactly P online analyses
//!    (one per analysis key), not T·P, and a single simulation.
//! 3. **Artifact persistence** — a cross-process resume that still has
//!    the `analysis/` store re-folds every row with zero simulations,
//!    zero replays and zero analyses; with only `traces/` left, one
//!    replay fans out into all P analyses.

use std::path::PathBuf;

use eva_cim::analyzer::LocalityRule;
use eva_cim::api::{sweep_section, Report};
use eva_cim::config::{CimLevels, SystemConfig, Technology};
use eva_cim::coordinator::{
    cross, persist, Coordinator, SweepOptions, SweepPoint, SweepRow,
};
use eva_cim::pipeline::run_pipelined;
use eva_cim::profiler::ProfileInputs;
use eva_cim::reshape::{reshape_from_deltas, DeltaSink};
use eva_cim::runtime::{Backend, NativeBackend};
use eva_cim::sim::Limits;
use eva_cim::workloads;

const PLACEMENTS: [CimLevels; 3] =
    [CimLevels::L1Only, CimLevels::L2Only, CimLevels::Both];

fn techs4() -> Vec<Technology> {
    vec![
        Technology::SRAM,
        Technology::FEFET,
        Technology::RRAM,
        Technology::STT_MRAM,
    ]
}

/// T = 4 technologies × P = 3 placements of one bench + geometry: twelve
/// design points sharing a single trace, three analysis keys.
fn grid_points() -> Vec<SweepPoint> {
    let base = SystemConfig::preset("c1").unwrap();
    let mut cfgs = Vec::new();
    for tech in techs4() {
        for cim in PLACEMENTS {
            let mut c = base.clone().with_tech(tech).with_cim(cim);
            c.name = format!("c1-{}-{}", tech.name(), cim.name());
            cfgs.push(c);
        }
    }
    cross(&["lcs"], &cfgs, LocalityRule::AnyCache)
}

fn opts(dir: Option<PathBuf>) -> SweepOptions {
    SweepOptions {
        scale: 4,
        workers: 2,
        cache_dir: dir,
        resume: true,
        ..Default::default()
    }
}

fn tmp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir()
        .join(format!("eva-cim-factored-{tag}-{}", std::process::id()))
}

fn dump_rows(rows: &[SweepRow]) -> Vec<String> {
    rows.iter().map(|r| persist::row_to_json(r).dump()).collect()
}

/// The unfactored reference path: one pipelined simulate + analyze +
/// reshape per design point (what the coordinator did before the stage
/// factoring), then one batched profiler evaluation in point order.
fn unfactored_rows(points: &[SweepPoint], opts: &SweepOptions) -> Vec<SweepRow> {
    let mut rows = Vec::new();
    let mut inputs = Vec::new();
    for p in points {
        let prog = workloads::build(&p.bench, opts.scale, opts.seed).unwrap();
        let limits = Limits { max_instructions: opts.max_instructions };
        let (summary, outcome, deltas) = run_pipelined(
            &prog,
            &p.config,
            limits,
            p.rule,
            DeltaSink::default(),
            None,
        )
        .unwrap();
        let reshaped = reshape_from_deltas(&summary, &deltas, &p.config);
        inputs.push(ProfileInputs::new(&p.config, &reshaped));
        rows.push(SweepRow {
            bench: p.bench.clone(),
            config_name: p.config.name.clone(),
            tech: p.config.tech,
            cim_levels: p.config.cim_levels,
            macr: outcome.macr,
            committed: summary.committed,
            cycles: summary.cycles,
            removed: reshaped.removed,
            cim_ops: reshaped.cim_op_count,
            result: Default::default(),
        });
    }
    let mut backend = NativeBackend;
    let results = backend.evaluate_batch(&inputs).unwrap();
    for (r, res) in rows.iter_mut().zip(results) {
        r.result = res;
    }
    rows
}

#[test]
fn t_techs_by_p_placements_run_exactly_p_analyses() {
    let points = grid_points();
    assert_eq!(points.len(), 12);
    let coord = Coordinator::new(opts(None));
    let (rows, stats) = coord
        .run_sweep_with_stats(&points, &mut NativeBackend)
        .unwrap();
    assert_eq!(rows.len(), 12);
    assert_eq!(stats.simulator_runs, 1, "one geometry, one simulation");
    assert_eq!(
        stats.analyses_run,
        PLACEMENTS.len() as u64,
        "P analyses, not T*P = {}",
        points.len()
    );
    assert_eq!(stats.analyses_cached, 0);
    assert_eq!(
        stats.replays_skipped,
        (points.len() - 1) as u64,
        "every point but the pass owner skips its replay"
    );
}

#[test]
fn factored_rows_are_byte_identical_to_the_unfactored_path() {
    let points = grid_points();
    let o = opts(None);
    let (factored, _) = Coordinator::new(o.clone())
        .run_sweep_with_stats(&points, &mut NativeBackend)
        .unwrap();
    let reference = unfactored_rows(&points, &o);

    // canonical row serialization, point by point
    assert_eq!(dump_rows(&factored), dump_rows(&reference));

    // and every rendering of the standard sweep report
    let a = Report::new("sweep results").with_section(sweep_section(&factored));
    let b = Report::new("sweep results").with_section(sweep_section(&reference));
    assert_eq!(a.render_table(), b.render_table());
    assert_eq!(a.render_csv(), b.render_csv());
    assert_eq!(a.render_json(), b.render_json());
}

#[test]
fn artifact_store_serves_cross_process_resumes_without_reanalysis() {
    let dir = tmp_dir("store");
    std::fs::remove_dir_all(&dir).ok();
    let points = grid_points();

    // cold populate: one simulation, P analyses, all persisted
    let (cold, s_cold) = Coordinator::new(opts(Some(dir.clone())))
        .run_sweep_with_stats(&points, &mut NativeBackend)
        .unwrap();
    assert_eq!(s_cold.simulator_runs, 1);
    assert_eq!(s_cold.analyses_run, PLACEMENTS.len() as u64);

    // fully-warm resume (fresh coordinator = fresh process state): rows
    // come straight from the result cache
    let (warm, s_warm) = Coordinator::new(opts(Some(dir.clone())))
        .run_sweep_with_stats(&points, &mut NativeBackend)
        .unwrap();
    assert_eq!(s_warm.rows_from_cache, points.len());
    assert_eq!(s_warm.analyses_run, 0);
    assert_eq!(dump_rows(&cold), dump_rows(&warm));

    // drop the result cache, keep traces/ + analysis/: every row
    // recomputes but the artifact store feeds the fold directly — no
    // simulation, no replay, no analysis
    std::fs::remove_file(dir.join("results.jsonl")).unwrap();
    let (refolded, s3) = Coordinator::new(opts(Some(dir.clone())))
        .run_sweep_with_stats(&points, &mut NativeBackend)
        .unwrap();
    assert_eq!(s3.rows_from_cache, 0);
    assert_eq!(s3.rows_computed, points.len());
    assert_eq!(s3.simulator_runs, 0);
    assert_eq!(s3.trace_disk_hits, 0, "artifacts make the replay unnecessary");
    assert_eq!(s3.analyses_run, 0);
    assert_eq!(s3.analyses_cached, PLACEMENTS.len() as u64);
    assert_eq!(s3.replays_skipped, points.len() as u64);
    assert_eq!(dump_rows(&cold), dump_rows(&refolded));

    // drop the artifacts too, keep only traces/: one chunked replay fans
    // out into all P analyses — still zero simulations
    std::fs::remove_file(dir.join("results.jsonl")).unwrap();
    std::fs::remove_dir_all(dir.join("analysis")).unwrap();
    let (replayed, s4) = Coordinator::new(opts(Some(dir.clone())))
        .run_sweep_with_stats(&points, &mut NativeBackend)
        .unwrap();
    assert_eq!(s4.simulator_runs, 0);
    assert_eq!(s4.trace_disk_hits, 1);
    assert_eq!(s4.analyses_run, PLACEMENTS.len() as u64);
    assert_eq!(dump_rows(&cold), dump_rows(&replayed));

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn locality_rules_get_their_own_analyses() {
    // same trace + placement under two locality rules must not share an
    // artifact: 2 rules × 2 techs = 4 points, 2 analyses, 1 simulation
    let base = SystemConfig::preset("c1").unwrap();
    let mut cfgs = Vec::new();
    for tech in [Technology::SRAM, Technology::FEFET] {
        let mut c = base.clone().with_tech(tech);
        c.name = format!("c1-{}", tech.name());
        cfgs.push(c);
    }
    let mut points = cross(&["lcs"], &cfgs, LocalityRule::AnyCache);
    points.extend(cross(&["lcs"], &cfgs, LocalityRule::SameBank));
    let (rows, stats) = Coordinator::new(opts(None))
        .run_sweep_with_stats(&points, &mut NativeBackend)
        .unwrap();
    assert_eq!(rows.len(), 4);
    assert_eq!(stats.simulator_runs, 1);
    assert_eq!(stats.analyses_run, 2, "each rule needs its own analysis");
}
