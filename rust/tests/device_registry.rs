//! The device-registry refactor contract:
//!
//! 1. the built-in SRAM/FeFET registry entries are **byte-identical** to
//!    the legacy closed-enum model — same `TECH_TABLE` parameters, and
//!    bit-for-bit equal `energy_latency` output across geometries (the
//!    legacy closed-form is re-implemented here as the oracle);
//! 2. a sweep cache written by a pre-registry build is treated as a
//!    *miss* (the key schema now covers device-model content), never as
//!    stale rows;
//! 3. a TOML-defined custom technology round-trips through the result
//!    cache under a content-hash key distinct from every built-in's.

use std::path::PathBuf;

use eva_cim::analyzer::LocalityRule;
use eva_cim::config::{parse, SystemConfig, Technology};
use eva_cim::coordinator::{cross, key, persist, Coordinator, SweepOptions};
use eva_cim::energy::calib::*;
use eva_cim::energy::{device, energy_latency, CfgRow};
use eva_cim::runtime::NativeBackend;
use eva_cim::util::json::Json;
use eva_cim::util::rng::Rng;

/// The pre-registry array model, verbatim: power-law interpolation over
/// the hardcoded two-row `TECH_TABLE` with the global anchor constants.
fn legacy_energy_latency(row: &CfgRow) -> ([f64; NOPS], [f64; NOPS]) {
    let cap = row[CFG_CAPACITY];
    let assoc = row[CFG_ASSOC].max(1.0);
    let banks = row[CFG_BANKS].max(1.0);
    let tech = (row[CFG_TECH] as usize).min(NTECH - 1);
    let t = &TECH_TABLE[tech];

    let ln4 = 4.0f64.ln();
    let ln2 = 2.0f64.ln();
    let cap_eff = cap * (ANCHOR_BANKS / banks);
    let cap_n = (cap_eff / ANCHOR_L1_CAP).ln();
    let assoc_f = (assoc / ANCHOR_ASSOC).powf(ASSOC_EXP);

    let mut energy = [0.0; NOPS];
    let mut lat = [0.0; NOPS];
    for j in 0..NOPS {
        let e1 = t[TP_E_L1 + j];
        let e2 = t[TP_E_L2 + j];
        let be = ((e2 / e1).ln() - ASSOC_EXP * ln2) / ln4;
        energy[j] = e1 * (be * cap_n).exp() * assoc_f;

        let l1 = t[TP_LAT_L1 + j];
        let l2 = t[TP_LAT_L2 + j];
        let bl = (l2 / l1).ln() / ln4;
        lat[j] = l1 * (bl * cap_n).exp();
    }
    (energy, lat)
}

#[test]
fn builtin_models_match_the_legacy_table_parameters() {
    assert_eq!(device::model_of(Technology::SRAM).params(), TECH_TABLE[0]);
    assert_eq!(device::model_of(Technology::FEFET).params(), TECH_TABLE[1]);
}

#[test]
fn registry_energy_latency_is_bit_identical_to_the_legacy_model() {
    // structured grid: every cap/assoc/banks/level corner the sweeps use
    for tech in 0..NTECH {
        for cap_kb in [8.0, 16.0, 32.0, 64.0, 256.0, 1024.0, 2048.0] {
            for assoc in [1.0, 2.0, 4.0, 8.0, 16.0] {
                for banks in [1.0, 2.0, 4.0, 8.0] {
                    for level in [1.0, 2.0] {
                        let row: CfgRow = [
                            cap_kb * 1024.0,
                            assoc,
                            64.0,
                            banks,
                            tech as f64,
                            level,
                        ];
                        let (e_new, l_new) = energy_latency(&row);
                        let (e_old, l_old) = legacy_energy_latency(&row);
                        // bit-for-bit, not approximately: the refactor
                        // must not perturb a single ulp
                        assert_eq!(e_new, e_old, "energy differs at {row:?}");
                        assert_eq!(l_new, l_old, "latency differs at {row:?}");
                    }
                }
            }
        }
    }
    // randomized geometries on top of the grid
    let mut rng = Rng::new(0xdecaf);
    for _ in 0..500 {
        let row: CfgRow = [
            (1 << rng.range(10, 22)) as f64,
            (1 << rng.range(0, 5)) as f64,
            64.0,
            (1 << rng.range(0, 4)) as f64,
            rng.range(0, NTECH) as f64,
            1.0 + rng.range(0, 2) as f64,
        ];
        assert_eq!(energy_latency(&row), legacy_energy_latency(&row));
    }
}

fn tmp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("eva-cim-devreg-{tag}-{}", std::process::id()))
}

fn opts(dir: PathBuf) -> SweepOptions {
    SweepOptions {
        scale: 4,
        workers: 2,
        cache_dir: Some(dir),
        resume: true,
        ..Default::default()
    }
}

/// The *pre-registry* point-key serialization, verbatim: the config's
/// technology was identified by name alone, with no device-model content.
fn legacy_point_key(
    bench: &str,
    cfg: &SystemConfig,
    rule: LocalityRule,
    o: &SweepOptions,
    backend: &str,
) -> String {
    let cache_to_json = |c: &eva_cim::config::CacheConfig| {
        Json::obj(vec![
            ("capacity", c.capacity.into()),
            ("assoc", c.assoc.into()),
            ("line", c.line.into()),
            ("banks", c.banks.into()),
            ("latency", c.latency.into()),
            ("mshr_entries", c.mshr_entries.into()),
        ])
    };
    let config = Json::obj(vec![
        ("name", cfg.name.as_str().into()),
        (
            "core",
            Json::obj(vec![
                ("width", cfg.core.width.into()),
                ("rob_entries", cfg.core.rob_entries.into()),
                ("iq_entries", cfg.core.iq_entries.into()),
                ("lsq_entries", cfg.core.lsq_entries.into()),
                ("mispredict_penalty", cfg.core.mispredict_penalty.into()),
                ("int_alu_units", cfg.core.int_alu_units.into()),
                ("int_mul_units", cfg.core.int_mul_units.into()),
                ("fp_units", cfg.core.fp_units.into()),
                ("mem_ports", cfg.core.mem_ports.into()),
            ]),
        ),
        ("l1i", cache_to_json(&cfg.l1i)),
        ("l1d", cache_to_json(&cfg.l1d)),
        ("l2", cache_to_json(&cfg.l2)),
        (
            "dram",
            Json::obj(vec![
                ("size", cfg.dram.size.into()),
                ("latency", cfg.dram.latency.into()),
            ]),
        ),
        ("tech", cfg.tech.name().into()),
        ("cim_levels", cfg.cim_levels.name().into()),
        ("clock_ghz", cfg.clock_ghz.into()),
    ]);
    let payload = Json::obj(vec![
        ("bench", bench.into()),
        ("scale", o.scale.into()),
        ("seed", o.seed.into()),
        ("max_instructions", o.max_instructions.into()),
        ("rule", rule.name().into()),
        ("backend", backend.into()),
        ("config", config),
    ])
    .dump();
    format!("{:016x}", key::fnv1a(payload.as_bytes()))
}

#[test]
fn pre_refactor_cache_rows_are_misses_not_stale_hits() {
    let dir = tmp_dir("legacy-miss");
    std::fs::remove_dir_all(&dir).ok();
    let points = cross(
        &["lcs"],
        &[SystemConfig::preset("c1").unwrap()],
        LocalityRule::AnyCache,
    );
    let o = opts(dir.clone());

    // compute once to obtain a structurally-valid row, then rewrite the
    // cache as a pre-registry build would have written it: same row JSON,
    // but filed under the *legacy* key (no tech_model in the payload)
    let (rows, _) = Coordinator::new(o.clone())
        .run_sweep_with_stats(&points, &mut NativeBackend)
        .unwrap();
    let legacy_key = legacy_point_key(
        "lcs",
        &points[0].config,
        LocalityRule::AnyCache,
        &o,
        "native",
    );
    let new_key = key::point_key(&points[0], &o, "native");
    assert_ne!(legacy_key, new_key, "key schema must have changed");

    std::fs::remove_dir_all(&dir).ok();
    let cache = eva_cim::coordinator::cache::ResultCache::open(&dir).unwrap();
    // poison the row so any stale hit is unmissable, then file it under
    // the legacy key only
    let mut stale = rows[0].clone();
    stale.result.improvement = -777.0;
    cache.append(&legacy_key, &stale).unwrap();
    drop(cache);

    let (resumed, stats) = Coordinator::new(o)
        .run_sweep_with_stats(&points, &mut NativeBackend)
        .unwrap();
    assert_eq!(stats.rows_from_cache, 0, "legacy row must not satisfy resume");
    assert_eq!(stats.rows_computed, points.len());
    assert!(stats.simulator_runs > 0 || stats.trace_disk_hits > 0);
    assert_ne!(resumed[0].result.improvement, -777.0);
    assert_eq!(
        persist::row_to_json(&resumed[0]).dump(),
        persist::row_to_json(&rows[0]).dump(),
        "recomputed row must match the honestly-computed one"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn toml_custom_tech_roundtrips_the_cache_with_a_distinct_key() {
    let dir = tmp_dir("custom-tech");
    std::fs::remove_dir_all(&dir).ok();

    let techs = parse::register_technologies(
        r#"
        [tech.devreg-ecram]
        base = "fefet"
        e_l1_write = 22.0
        e_l2_write = 46.0
        "#,
    )
    .unwrap();
    let custom = techs[0];
    assert_eq!(custom.name(), "devreg-ecram");

    let mut configs = Vec::new();
    for tech in [Technology::SRAM, Technology::FEFET, custom] {
        let mut c = SystemConfig::preset("c1").unwrap().with_tech(tech);
        c.name = format!("c1-{}", tech.name());
        configs.push(c);
    }
    let points = cross(&["lcs"], &configs, LocalityRule::AnyCache);
    // one worker so the three same-geometry variants provably share one
    // simulation (parallel workers may legitimately race to cold-simulate)
    let o = SweepOptions { workers: 1, ..opts(dir.clone()) };

    // the custom tech's key differs from every built-in's even though the
    // geometry is identical
    let keys: Vec<String> =
        points.iter().map(|p| key::point_key(p, &o, "native")).collect();
    assert_eq!(keys.len(), 3);
    assert_ne!(keys[2], keys[0]);
    assert_ne!(keys[2], keys[1]);

    let (cold, cold_stats) = Coordinator::new(o.clone())
        .run_sweep_with_stats(&points, &mut NativeBackend)
        .unwrap();
    // one geometry, three tech variants: a single simulation serves all
    assert_eq!(cold_stats.simulator_runs, 1);

    // fully-warm resume from a fresh coordinator: byte-identical rows,
    // nothing recomputed — the custom row comes back from disk
    let (warm, warm_stats) = Coordinator::new(o)
        .run_sweep_with_stats(&points, &mut NativeBackend)
        .unwrap();
    assert_eq!(warm_stats.rows_from_cache, 3);
    assert_eq!(warm_stats.simulator_runs, 0);
    for (c, w) in cold.iter().zip(&warm) {
        assert_eq!(
            persist::row_to_json(c).dump(),
            persist::row_to_json(w).dump()
        );
    }
    assert_eq!(warm[2].tech, custom);
    // cheaper writes than FeFET must show up as a real model difference
    assert_ne!(
        cold[2].result.total_cim, cold[1].result.total_cim,
        "custom coefficients must change the evaluation"
    );
    std::fs::remove_dir_all(&dir).ok();
}
