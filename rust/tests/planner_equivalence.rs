//! Offload-planner contracts (PR 9).
//!
//! 1. **Accept-all byte-identity** — the default planner policy is the
//!    "off" state: feeding a pipelined run through a
//!    `planner::PlanSink` with [`PlanPolicy::AcceptAll`] must leave the
//!    analysis artifact (stream outcome + reshape deltas) and the
//!    rendered Report JSON / table / CSV byte-identical to a bare
//!    `DeltaSink`, across randomized bench × locality rule × CiM
//!    placement × technology draws.
//! 2. **Profitability rejects with priced reasons** — on a memory-bound
//!    benchmark the profitability policy rejects at least one candidate
//!    group, every rejection carries a non-empty cost ledger and one of
//!    the three machine-readable reasons, and the same rejection is
//!    visible through the `Evaluation::plan()` facade the CLI calls.
//!
//! The per-reason reachability/serialization unit tests live next to the
//! planner (`rust/src/planner/mod.rs`); this suite pins the end-to-end
//! pipeline contracts.

use eva_cim::analyzer::LocalityRule;
use eva_cim::api::{BackendSel, Cell, Evaluation, Report, Section};
use eva_cim::config::{CimLevels, SystemConfig, Technology};
use eva_cim::coordinator::analysis_store::{artifact_to_json, AnalysisArtifact};
use eva_cim::pipeline::run_pipelined;
use eva_cim::planner::{PlanPolicy, PlanSink, RejectReason};
use eva_cim::profiler::{evaluate_native_batch, ProfileInputs};
use eva_cim::reshape::{reshape_from_deltas, DeltaSink};
use eva_cim::sim::Limits;
use eva_cim::util::proptest::check;
use eva_cim::workloads;

const BENCHES: [&str; 3] = ["lcs", "km", "bfs"];
const RULES: [LocalityRule; 3] =
    [LocalityRule::AnyCache, LocalityRule::SameLevel, LocalityRule::SameBank];
const PLACEMENTS: [CimLevels; 3] =
    [CimLevels::L1Only, CimLevels::L2Only, CimLevels::Both];

/// Fold deltas through reshape + the native energy model into a small
/// Report — the same value path a sweep row takes, so byte-equality here
/// means byte-equality of everything downstream of the planner.
fn report_for(
    cfg: &SystemConfig,
    summary: &eva_cim::probes::TraceSummary,
    deltas: &DeltaSink,
) -> Report {
    let r = reshape_from_deltas(summary, deltas, cfg);
    let p = evaluate_native_batch(&[ProfileInputs::new(cfg, &r)]).remove(0);
    let mut s = Section::new(
        "planner equivalence probe",
        &["removed", "cim ops", "E-base", "E-cim", "E-impr", "speedup"],
    );
    s.row(vec![
        Cell::int(r.removed),
        Cell::int(r.cim_op_count),
        Cell::num(p.total_base, 6),
        Cell::num(p.total_cim, 6),
        Cell::num(p.improvement, 6),
        Cell::num(p.speedup, 6),
    ]);
    Report::new("planner equivalence probe").with_section(s)
}

#[test]
fn accept_all_is_byte_identical_to_the_planner_free_pipeline() {
    check(
        "planner-accept-all-byte-identity",
        9,
        |rng, _size| {
            let bench = BENCHES[rng.gen_range(BENCHES.len() as u64) as usize];
            let rule = RULES[rng.gen_range(RULES.len() as u64) as usize];
            let cim =
                PLACEMENTS[rng.gen_range(PLACEMENTS.len() as u64) as usize];
            let techs = Technology::all();
            let tech = techs[rng.gen_range(techs.len() as u64) as usize];
            let seed = rng.gen_range(1000);
            (bench, rule, cim, tech, seed)
        },
        |&(bench, rule, cim, tech, seed)| {
            let cfg = SystemConfig::preset("c1")
                .unwrap()
                .with_tech(tech)
                .with_cim(cim);
            let prog = workloads::build(bench, 2, seed)
                .ok_or_else(|| format!("unknown benchmark '{bench}'"))?;

            let (sum_a, out_a, deltas_a) = run_pipelined(
                &prog,
                &cfg,
                Limits::default(),
                rule,
                DeltaSink::default(),
                None,
            )
            .map_err(|e| format!("bare run: {e:#}"))?;

            let (sum_b, out_b, sink) = run_pipelined(
                &prog,
                &cfg,
                Limits::default(),
                rule,
                PlanSink::new(
                    &cfg,
                    PlanPolicy::AcceptAll,
                    PlanPolicy::AcceptAll.default_knobs(),
                ),
                None,
            )
            .map_err(|e| format!("planned run: {e:#}"))?;
            let (plan, deltas_b) = sink.finish();

            if plan.groups_rejected() != 0 {
                return Err(format!(
                    "accept-all rejected {} groups",
                    plan.groups_rejected()
                ));
            }
            if plan.groups_accepted() != plan.decisions.len() as u64 {
                return Err("accepted count != decision count".into());
            }

            // artifact bytes: summary + stream outcome + reshape deltas
            let art_a = artifact_to_json(&AnalysisArtifact::new(
                sum_a.clone(),
                out_a,
                deltas_a.clone(),
            ))
            .dump();
            let art_b = artifact_to_json(&AnalysisArtifact::new(
                sum_b.clone(),
                out_b,
                deltas_b.clone(),
            ))
            .dump();
            if art_a != art_b {
                return Err("analysis artifact bytes diverged".into());
            }

            // rendered bytes: JSON, table and CSV of the folded report
            let rep_a = report_for(&cfg, &sum_a, &deltas_a);
            let rep_b = report_for(&cfg, &sum_b, &deltas_b);
            if rep_a.render_json() != rep_b.render_json() {
                return Err("report JSON diverged".into());
            }
            if rep_a.render_table() != rep_b.render_table() {
                return Err("report table diverged".into());
            }
            if rep_a.render_csv() != rep_b.render_csv() {
                return Err("report CSV diverged".into());
            }
            Ok(())
        },
    );
}

#[test]
fn profitability_rejects_groups_with_priced_reasons() {
    let cfg = SystemConfig::preset("c1").unwrap();
    let knobs = PlanPolicy::Profitability.default_knobs();
    let names: Vec<&str> =
        RejectReason::all().iter().map(|r| r.name()).collect();

    let mut rejecting_bench = None;
    for bench in BENCHES {
        let prog = workloads::build(bench, 3, 3).unwrap();
        let (_, _, sink) = run_pipelined(
            &prog,
            &cfg,
            Limits::default(),
            LocalityRule::AnyCache,
            PlanSink::new(&cfg, PlanPolicy::Profitability, knobs),
            None,
        )
        .unwrap();
        let (plan, _) = sink.finish();
        for d in plan.decisions.iter().filter(|d| !d.accepted()) {
            let reason = d.rejected.expect("rejected has a reason").name();
            assert!(
                names.contains(&reason),
                "{bench}: unknown rejection reason {reason}"
            );
            assert!(
                d.ledger.terms().iter().any(|&(_, v)| v != 0.0),
                "{bench}: rejected group has an empty cost ledger"
            );
            // the reason round-trips through the canonical JSON
            assert!(
                d.to_json().dump().contains(&format!("\"rejected\":\"{reason}\"")),
                "{bench}: reason missing from decision JSON"
            );
        }
        if plan.groups_rejected() >= 1 && rejecting_bench.is_none() {
            assert!(
                plan.rejected_energy_pj() >= 0.0,
                "{bench}: negative rejected energy"
            );
            rejecting_bench = Some(bench);
        }
    }
    let bench = rejecting_bench.expect(
        "profitability accepted every group on every memory-bound bench",
    );

    // the same rejection is visible through the facade the CLI calls
    let report = Evaluation::new()
        .bench(bench)
        .preset("c1")
        .scale(3)
        .seed(3)
        .jobs(2)
        .backend(BackendSel::Native)
        .policy(PlanPolicy::Profitability)
        .plan()
        .unwrap();
    let json = report.render_json();
    assert!(
        json.contains("\"decision\":\"reject\""),
        "{bench}: plan report shows no rejected group"
    );
    assert!(
        names.iter().any(|n| json.contains(&format!("\"reason\":\"{n}\""))),
        "{bench}: plan report carries no machine-readable reason"
    );
}
