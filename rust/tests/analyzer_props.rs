//! Property-based tests over the analyzer (IDG, selection, reshaping) using
//! randomly generated straight-line-plus-loop programs.

use eva_cim::analyzer::{analyze, build_forest, LocalityRule};
use eva_cim::asm::Asm;
use eva_cim::config::SystemConfig;
use eva_cim::reshape::{reshape, counters::*};
use eva_cim::sim::{simulate, Limits};
use eva_cim::util::proptest::check;
use eva_cim::util::Rng;

/// Generate a random but always-terminating program mixing convertible
/// patterns, scalar arithmetic and memory traffic.
fn random_program(rng: &mut Rng, size: u32) -> Asm {
    let mut a = Asm::new("prop");
    let words = 64 + 8 * size;
    let init: Vec<i32> = (0..words).map(|i| i as i32 * 3 + 1).collect();
    let buf = a.data.alloc_i32("buf", &init);
    a.li(1, buf as i32);
    // warm a few lines so some operands live in L1
    for k in 0..4 {
        a.lw(9, 1, k * 64);
    }
    let blocks = 2 + size % 8;
    for b in 0..blocks {
        let off = ((b * 12) % (words - 8)) as i32 * 4;
        match rng.gen_range(6) {
            0 => {
                // canonical load-load-op-store
                a.lw(2, 1, off);
                a.lw(3, 1, off + 4);
                match rng.gen_range(4) {
                    0 => a.add(4, 2, 3),
                    1 => a.and(4, 2, 3),
                    2 => a.or(4, 2, 3),
                    _ => a.xor(4, 2, 3),
                };
                a.sw(4, 1, off + 8);
            }
            1 => {
                // imm variant
                a.lw(2, 1, off);
                a.addi(4, 2, rng.gen_range(100) as i32);
                a.sw(4, 1, off);
            }
            2 => {
                // non-convertible mul chain
                a.lw(2, 1, off);
                a.mul(4, 2, 2);
                a.sw(4, 1, off + 4);
            }
            3 => {
                // chained reduction
                a.lw(2, 1, off);
                a.lw(3, 1, off + 4);
                a.add(5, 2, 3);
                a.lw(6, 1, off + 8);
                a.add(5, 5, 6);
                a.sw(5, 1, off + 12);
            }
            4 => {
                // scalar-only block
                a.addi(7, 7, 1);
                a.slli(8, 7, 2);
            }
            _ => {
                // store of a loaded value (copy, not convertible)
                a.lw(2, 1, off);
                a.sw(2, 1, off + 16);
            }
        }
    }
    a.halt();
    a
}

fn run(rng: &mut Rng, size: u32) -> (eva_cim::probes::Trace, SystemConfig) {
    let cfg = SystemConfig::preset("c1").unwrap();
    let prog = random_program(rng, size).assemble();
    let trace = simulate(&prog, &cfg, Limits::default()).unwrap();
    (trace, cfg)
}

#[test]
fn prop_idg_edges_point_backwards() {
    check(
        "idg-edges-backward",
        60,
        |rng, size| {
            let (trace, _) = run(rng, size);
            trace
        },
        |trace| {
            let f = build_forest(&trace.ciq);
            for n in &f.nodes {
                for c in n.children {
                    use eva_cim::analyzer::idg::Child;
                    match c {
                        Child::Load(s) | Child::External(s) => {
                            if s >= n.seq {
                                return Err(format!("edge {s} !< {}", n.seq));
                            }
                        }
                        Child::Node(i) => {
                            if f.nodes[i].seq >= n.seq {
                                return Err("node edge forward".into());
                            }
                        }
                        _ => {}
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_macr_in_unit_interval_and_consistent() {
    check(
        "macr-unit-interval",
        60,
        |rng, size| {
            let (trace, cfg) = run(rng, size);
            analyze(&trace, &cfg, LocalityRule::AnyCache).macr
        },
        |m| {
            if !(0.0..=1.0).contains(&m.ratio()) {
                return Err(format!("macr {}", m.ratio()));
            }
            if m.convertible != m.convertible_l1 + m.convertible_other {
                return Err("breakdown mismatch".into());
            }
            if m.convertible > m.total_accesses {
                return Err("convertible > total".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_candidates_claim_disjoint_instructions() {
    check(
        "candidates-disjoint",
        60,
        |rng, size| {
            let (trace, cfg) = run(rng, size);
            analyze(&trace, &cfg, LocalityRule::AnyCache).selection
        },
        |sel| {
            let mut seen = std::collections::HashSet::new();
            for c in &sel.candidates {
                for s in c
                    .members
                    .iter()
                    .chain(c.loads.iter())
                    .chain(c.absorbed_store.iter())
                {
                    if !seen.insert(*s) {
                        return Err(format!("seq {s} claimed twice"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_reshape_conserves_instructions_and_stays_nonnegative() {
    check(
        "reshape-conservation",
        60,
        |rng, size| {
            let (trace, cfg) = run(rng, size);
            let an = analyze(&trace, &cfg, LocalityRule::AnyCache);
            let r = reshape(&trace, &an.selection, &cfg);
            (trace.committed, r)
        },
        |(committed, r)| {
            let diff = r.base[C_FETCH] - r.cim[C_FETCH] - r.removed as f64;
            if diff.abs() > 1e-6 {
                return Err(format!("fetch conservation off by {diff}"));
            }
            if r.base[C_FETCH] as u64 != *committed {
                return Err("base fetch != committed".into());
            }
            for (i, v) in r.cim.0.iter().enumerate() {
                if *v < 0.0 {
                    return Err(format!("counter {i} negative"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_locality_rules_monotone() {
    check(
        "locality-monotone",
        40,
        |rng, size| run(rng, size),
        |(trace, cfg)| {
            let any = analyze(trace, cfg, LocalityRule::AnyCache).macr.convertible;
            let lvl = analyze(trace, cfg, LocalityRule::SameLevel).macr.convertible;
            let bank = analyze(trace, cfg, LocalityRule::SameBank).macr.convertible;
            if lvl > any || bank > lvl {
                return Err(format!("not monotone: {any} {lvl} {bank}"));
            }
            Ok(())
        },
    );
}
