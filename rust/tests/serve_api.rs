//! The serving contract (docs/SERVING.md): a served `Report` is
//! byte-identical to CLI `--format json`, a warm server answers repeated
//! requests from the coordinator caches with zero new simulations
//! (ledger-verified), N concurrent identical requests share exactly one
//! computation, and errors come back as the documented envelope without
//! destabilising the server.  The fault-domain probes at the bottom pin
//! the hardening contract: a request past `--request-timeout` gets a
//! `504` and frees its worker, a stalled client is shed by the socket
//! timeout without holding a slot, and a poisoned cache surfaces its
//! quarantine counters through the ledger header and `/stats`.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use eva_cim::api::{BackendSel, Evaluation};
use eva_cim::config::Technology;
use eva_cim::serve::{ServeOptions, Server, ServerHandle};

/// Spawn a test server on a free port with small, fast defaults.
fn test_server() -> ServerHandle {
    let opts = ServeOptions {
        addr: "127.0.0.1:0".into(),
        http_workers: 4,
        queue: 16,
        base: Evaluation::new().scale(2).jobs(2).backend(BackendSel::Native),
        ..ServeOptions::default()
    };
    Server::bind(opts).expect("bind").spawn().expect("spawn")
}

/// One raw HTTP exchange (the server closes after each response).
struct Reply {
    status: u16,
    headers: Vec<(String, String)>,
    body: String,
}

impl Reply {
    fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(n, _)| n.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }
}

fn exchange(addr: std::net::SocketAddr, request: &str) -> Reply {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.write_all(request.as_bytes()).expect("send");
    let mut raw = String::new();
    stream.read_to_string(&mut raw).expect("recv");
    let (head, body) = raw.split_once("\r\n\r\n").expect("complete response");
    let mut lines = head.lines();
    let status_line = lines.next().expect("status line");
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .expect("status code");
    let headers = lines
        .filter_map(|l| l.split_once(": "))
        .map(|(n, v)| (n.to_string(), v.to_string()))
        .collect();
    Reply { status, headers, body: body.to_string() }
}

fn get(addr: std::net::SocketAddr, path: &str) -> Reply {
    exchange(addr, &format!("GET {path} HTTP/1.1\r\nHost: t\r\n\r\n"))
}

fn post(addr: std::net::SocketAddr, path: &str, body: &str) -> Reply {
    exchange(
        addr,
        &format!(
            "POST {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        ),
    )
}

/// Pull one `"counter":"<name>","value":N` pair out of a /stats body.
fn stat_counter(stats_body: &str, name: &str) -> Option<u64> {
    let tag = format!("\"counter\":\"{name}\",\"value\":");
    let at = stats_body.find(&tag)? + tag.len();
    let rest = &stats_body[at..];
    let end = rest.find(|c: char| !c.is_ascii_digit())?;
    rest[..end].parse().ok()
}

#[test]
fn repeated_evaluate_is_served_from_cache_with_zero_new_simulations() {
    let server = test_server();
    let addr = server.addr();
    let body = r#"{"bench":"lcs","config":"c1","tech":"sram"}"#;

    let first = post(addr, "/evaluate", body);
    assert_eq!(first.status, 200, "first evaluate: {}", first.body);
    assert_eq!(first.header("X-Eva-Cache"), Some("computed"));
    let ledger = first.header("X-Eva-Ledger").expect("ledger header");
    assert!(
        ledger.contains("\"simulator_runs\":1"),
        "cold request simulates once: {ledger}"
    );

    let second = post(addr, "/evaluate", body);
    assert_eq!(second.status, 200);
    assert_eq!(second.header("X-Eva-Cache"), Some("cached"));
    let ledger = second.header("X-Eva-Ledger").expect("ledger header");
    assert!(
        ledger.contains("\"simulator_runs\":0"),
        "warm request simulates nothing: {ledger}"
    );
    assert_eq!(first.body, second.body, "cache replay is byte-identical");

    // formatting / key order must not defeat the cache
    let third = post(
        addr,
        "/evaluate",
        "{ \"tech\": \"sram\", \"config\": \"c1\",\n  \"bench\": \"lcs\" }",
    );
    assert_eq!(third.status, 200);
    assert_eq!(third.header("X-Eva-Cache"), Some("cached"));
    assert_eq!(first.body, third.body);

    // the cumulative /stats ledger agrees: one simulation total
    let stats = get(addr, "/stats");
    assert_eq!(stats.status, 200);
    assert_eq!(stat_counter(&stats.body, "simulator_runs"), Some(1));

    server.shutdown();
}

#[test]
fn concurrent_identical_requests_share_one_computation() {
    let server = test_server();
    let addr = server.addr();
    let body = r#"{"bench":"km","config":"c1","tech":"sram"}"#;

    let replies: Vec<Reply> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..4)
            .map(|_| s.spawn(move || post(addr, "/evaluate", body)))
            .collect();
        handles.into_iter().map(|h| h.join().expect("client")).collect()
    });

    let mut bodies: Vec<&str> = Vec::new();
    for r in &replies {
        assert_eq!(r.status, 200, "{}", r.body);
        assert!(r.header("X-Eva-Cache").is_some());
        bodies.push(&r.body);
    }
    bodies.dedup();
    assert_eq!(bodies.len(), 1, "all riders see the leader's bytes");

    // however the four interleaved, only one simulation ever ran
    let stats = get(addr, "/stats");
    assert_eq!(stat_counter(&stats.body, "simulator_runs"), Some(1));

    server.shutdown();
}

#[test]
fn served_report_is_byte_identical_to_the_cli_json_format() {
    let server = test_server();
    let addr = server.addr();

    let reply = post(
        addr,
        "/evaluate",
        r#"{"bench":"lcs","config":"c1","tech":"sram","scale":2,"seed":42}"#,
    );
    assert_eq!(reply.status, 200, "{}", reply.body);

    let direct = Evaluation::new()
        .bench("lcs")
        .preset("c1")
        .tech(Technology::SRAM)
        .scale(2)
        .seed(42)
        .jobs(2)
        .backend(BackendSel::Native)
        .run()
        .expect("direct run")
        .render_json();
    assert_eq!(reply.body, direct, "the canonical Report IS the wire format");

    // GET /list serves the same bytes as `eva-cim list --format json`
    let list = get(addr, "/list");
    assert_eq!(list.status, 200);
    assert_eq!(list.body, eva_cim::api::list_report().render_json());

    server.shutdown();
}

#[test]
fn errors_use_the_envelope_and_leave_the_server_healthy() {
    let server = test_server();
    let addr = server.addr();

    // unknown benchmark: 400, documented envelope, no cache header
    let r = post(addr, "/evaluate", r#"{"bench":"nope"}"#);
    assert_eq!(r.status, 400);
    assert!(r.header("X-Eva-Cache").is_none());
    assert!(r.body.starts_with("{\"error\":{\"code\":400,"), "{}", r.body);
    assert!(r.body.contains("\"schema\":1"));

    // malformed JSON: 400
    let r = post(addr, "/evaluate", "{not json");
    assert_eq!(r.status, 400);

    // unknown field: 400 (allow-list), names the field
    let r = post(addr, "/evaluate", r#"{"bench":"lcs","benc":"typo"}"#);
    assert_eq!(r.status, 400);
    assert!(r.body.contains("benc"), "{}", r.body);

    // unknown route / wrong method
    assert_eq!(get(addr, "/nope").status, 404);
    assert_eq!(post(addr, "/health", "{}").status, 405);

    // ... and none of that hurt the server
    let health = get(addr, "/health");
    assert_eq!(health.status, 200);
    assert!(health.body.contains("\"status\":\"ok\""));

    server.shutdown();
}

#[test]
fn a_request_past_the_deadline_gets_a_504_and_the_worker_is_freed() {
    // one worker and a deadline no computation can beat: the 504 path
    // must hand the worker back while the evaluation finishes detached
    let opts = ServeOptions {
        addr: "127.0.0.1:0".into(),
        http_workers: 1,
        queue: 16,
        request_timeout: Some(Duration::from_nanos(1)),
        base: Evaluation::new().scale(2).jobs(2).backend(BackendSel::Native),
        ..ServeOptions::default()
    };
    let server = Server::bind(opts).expect("bind").spawn().expect("spawn");
    let addr = server.addr();

    let r = post(addr, "/evaluate", r#"{"bench":"lcs","config":"c1","tech":"sram"}"#);
    assert_eq!(r.status, 504, "{}", r.body);
    assert!(r.body.starts_with("{\"error\":{\"code\":504,"), "{}", r.body);
    assert!(r.body.contains("request-timeout"), "{}", r.body);

    // the lone worker is free again — non-evaluating routes answer at
    // once (they never go through the deadline path)
    let health = get(addr, "/health");
    assert_eq!(health.status, 200);
    assert!(health.body.contains("\"status\":\"ok\""));
    assert_eq!(get(addr, "/stats").status, 200);

    server.shutdown();
}

#[test]
fn a_stalled_client_is_disconnected_without_holding_the_worker_slot() {
    let opts = ServeOptions {
        addr: "127.0.0.1:0".into(),
        http_workers: 1,
        queue: 16,
        socket_timeout: Duration::from_millis(200),
        base: Evaluation::new().scale(2).jobs(2).backend(BackendSel::Native),
        ..ServeOptions::default()
    };
    let server = Server::bind(opts).expect("bind").spawn().expect("spawn");
    let addr = server.addr();

    // half a request, then silence: the lone worker blocks reading it
    let mut stalled = TcpStream::connect(addr).expect("connect");
    stalled.write_all(b"POST /evaluate HTTP/1.1\r\n").expect("send partial");
    std::thread::sleep(Duration::from_millis(50));

    // the socket timeout must shed the stalled client so this is served
    let health = get(addr, "/health");
    assert_eq!(health.status, 200, "{}", health.body);

    // and the server closed the stalled connection (a 400 envelope may
    // arrive first; what matters is reaching EOF, not what precedes it)
    stalled
        .set_read_timeout(Some(Duration::from_secs(10)))
        .expect("read timeout");
    let mut drained = Vec::new();
    stalled
        .read_to_end(&mut drained)
        .expect("server closes the stalled connection");

    server.shutdown();
}

#[test]
fn a_poisoned_cache_surfaces_quarantine_counters_through_stats() {
    let dir = std::env::temp_dir().join(format!(
        "eva-cim-serve-quarantine-{}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("results.jsonl"), "garbage not json\n").unwrap();

    let opts = ServeOptions {
        addr: "127.0.0.1:0".into(),
        http_workers: 2,
        queue: 16,
        base: Evaluation::new()
            .scale(2)
            .jobs(2)
            .backend(BackendSel::Native)
            .cache_dir(&dir)
            .resume(true),
        ..ServeOptions::default()
    };
    let server = Server::bind(opts).expect("bind").spawn().expect("spawn");
    let addr = server.addr();

    // the poisoned line quarantines on the resume load; the request
    // still answers 200 and its ledger reports the quarantine
    let r = post(addr, "/evaluate", r#"{"bench":"lcs","config":"c1","tech":"sram"}"#);
    assert_eq!(r.status, 200, "{}", r.body);
    let ledger = r.header("X-Eva-Ledger").expect("ledger header");
    assert!(
        ledger.contains("\"entries_quarantined\":1"),
        "quarantine surfaces in the ledger: {ledger}"
    );
    assert!(ledger.contains("\"degraded_mode\":false"), "{ledger}");

    // quarantine is content-addressed, so a second load of the same
    // poisoned file counts nothing new
    let r2 = post(addr, "/evaluate", r#"{"bench":"km","config":"c1","tech":"sram"}"#);
    assert_eq!(r2.status, 200, "{}", r2.body);
    let ledger2 = r2.header("X-Eva-Ledger").expect("ledger header");
    assert!(ledger2.contains("\"entries_quarantined\":0"), "{ledger2}");

    // ... and the cumulative /stats ledger carries the fault counters
    let stats = get(addr, "/stats");
    assert_eq!(stats.status, 200);
    assert_eq!(stat_counter(&stats.body, "entries_quarantined"), Some(1));
    assert_eq!(stat_counter(&stats.body, "io_retries"), Some(0));
    assert_eq!(stat_counter(&stats.body, "degraded_mode"), Some(0));

    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}
