//! Golden-file contracts for the machine-readable report path
//! (ISSUE 4 satellite): `--format json` output for `table5` and `explore`
//! must round-trip through the JSON layer and be byte-stable across runs —
//! including a cache-warm rerun, which must serialize byte-identically to
//! the cold run that populated the cache.

use eva_cim::analyzer::LocalityRule;
use eva_cim::config::{CimLevels, Technology};
use eva_cim::coordinator::SweepOptions;
use eva_cim::experiments;
use eva_cim::runtime::NativeBackend;
use eva_cim::util::json;

fn fast_opts() -> SweepOptions {
    SweepOptions { scale: 2, workers: 2, ..Default::default() }
}

/// The structural golden: canonical JSON documents parse, re-dump to the
/// same bytes, and carry the schema/section envelope.
fn assert_canonical(doc: &str) -> json::Json {
    let parsed = json::parse(doc.trim_end()).expect("report JSON must parse");
    assert_eq!(
        parsed.dump(),
        doc.trim_end(),
        "canonical JSON must re-dump byte-identically"
    );
    assert_eq!(parsed.get("schema").unwrap().as_u64(), Some(1));
    assert!(!parsed.get("sections").unwrap().as_arr().unwrap().is_empty());
    parsed
}

#[test]
fn table3_json_matches_the_golden_envelope() {
    let report = experiments::table3();
    let doc = report.render_json();
    let parsed = assert_canonical(&doc);
    // golden structural facts: first section, its columns, and the exact
    // published SRAM-L1 anchor row (Table III, paper §V-B)
    let s0 = parsed.get("sections").unwrap().idx(0).unwrap();
    let cols: Vec<&str> = s0
        .get("columns")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|c| c.as_str().unwrap())
        .collect();
    assert_eq!(
        cols,
        ["tech", "level", "config", "non-CiM read", "CiM-OR", "CiM-AND",
         "CiM-XOR", "CiM-ADDW32"]
    );
    let row0 = s0.get("rows").unwrap().idx(0).unwrap();
    assert_eq!(row0.get("tech").unwrap().as_str(), Some("SRAM"));
    assert_eq!(row0.get("level").unwrap().as_str(), Some("L1"));
    assert_eq!(row0.get("non-CiM read").unwrap().as_f64().unwrap().round(), 61.0);
    assert_eq!(row0.get("CiM-ADDW32").unwrap().as_f64().unwrap().round(), 79.0);
}

#[test]
fn table5_json_roundtrips_and_is_byte_stable() {
    let a = experiments::table5(&mut NativeBackend, 2).unwrap().render_json();
    let b = experiments::table5(&mut NativeBackend, 2).unwrap().render_json();
    assert_eq!(a, b, "table5 JSON must be byte-stable across runs");
    let parsed = assert_canonical(&a);
    // the deviation row carries raw fractions, not percent strings
    let rows = parsed
        .get("sections")
        .unwrap()
        .idx(0)
        .unwrap()
        .get("rows")
        .unwrap()
        .as_arr()
        .unwrap();
    assert_eq!(rows.len(), 3);
    assert_eq!(rows[2].get("model").unwrap().as_str(), Some("Deviation"));
    assert!(rows[2].get("CiM").unwrap().as_f64().is_some());
}

#[test]
fn explore_json_is_byte_identical_cold_vs_cached() {
    let dir = std::env::temp_dir()
        .join(format!("eva-cim-golden-cache-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let opts = SweepOptions {
        cache_dir: Some(dir.clone()),
        resume: true,
        ..fast_opts()
    };
    let run = |opts: SweepOptions| {
        experiments::explore(
            &["lcs"],
            &[Technology::SRAM, Technology::FEFET],
            &["c1", "c2"],
            CimLevels::Both,
            LocalityRule::AnyCache,
            opts,
            &mut NativeBackend,
        )
        .unwrap()
    };
    let cold = run(opts.clone());
    let warm = run(opts);
    // the warm run must have served every point from the cache...
    assert_eq!(warm.stats.as_ref().unwrap().rows_from_cache, 4);
    assert_eq!(warm.stats.as_ref().unwrap().simulator_runs, 0);
    // ...and still serialize byte-identically in every format
    assert_eq!(cold.render_json(), warm.render_json());
    assert_eq!(cold.render_csv(), warm.render_csv());
    assert_eq!(cold.render_table(), warm.render_table());
    let parsed = assert_canonical(&cold.render_json());
    // grid + frontier sections; the grid carries Pareto marks as booleans
    let sections = parsed.get("sections").unwrap().as_arr().unwrap();
    assert_eq!(sections.len(), 2);
    let grid_rows = sections[0].get("rows").unwrap().as_arr().unwrap();
    assert_eq!(grid_rows.len(), 4, "2 techs x 2 configs on 1 bench");
    assert!(grid_rows
        .iter()
        .any(|r| r.get("Pareto").unwrap().as_bool() == Some(true)));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn explore_csv_goes_through_the_report_renderer() {
    let report = experiments::explore(
        &["lcs"],
        &[Technology::SRAM],
        &["c1"],
        CimLevels::Both,
        LocalityRule::AnyCache,
        fast_opts(),
        &mut NativeBackend,
    )
    .unwrap();
    let csv = report.render_csv();
    // multi-section CSV: one block per section, titled
    assert!(csv.starts_with("# explore"));
    let grid_header = csv.lines().nth(1).unwrap();
    assert_eq!(grid_header, "bench,tech,config,MACR,E-impr,speedup,Pareto");
    // single-bench single-tech single-config grid: the lone point is on
    // the frontier by construction
    assert!(csv.contains("LCS,sram,c1"));
}
