//! The chaos suite: deterministic I/O fault schedules against the sweep
//! stores (PR "fault-domain hardening" acceptance harness).
//!
//! Contract under test — for every seeded fault schedule, a sweep either
//! completes **byte-identical** to the fault-free run or fails with a
//! typed error; never a panic, never a wedge, never a wrong cached row.
//! Transient faults (`EINTR`/`EAGAIN`) are absorbed by retries and
//! surface only as ledger counters; hard faults on cache writes degrade
//! the sweep to in-memory operation (`degraded_mode`) without changing
//! any result byte; corrupt store entries are quarantined exactly once
//! and can never re-poison a warm resume.
//!
//! The injector ([`eva_cim::util::faultio`]) is process-global, so every
//! test here serializes on one lock and disarms via a drop guard — the
//! same discipline as the faultio unit tests.

use std::path::{Path, PathBuf};
use std::sync::Mutex;

use eva_cim::analyzer::LocalityRule;
use eva_cim::config::SystemConfig;
use eva_cim::coordinator::{
    cross, persist, Coordinator, SweepOptions, SweepPoint, SweepRow, SweepStats,
};
use eva_cim::runtime::NativeBackend;
use eva_cim::util::faultio::{self, FaultKind, FaultPlan, FaultSpec, IoOp};
use eva_cim::util::lock_unpoisoned;

/// Serializes every test in this binary around the process-global
/// injector (and the process-global fault telemetry the ledger samples).
static FAULT_LOCK: Mutex<()> = Mutex::new(());

/// Disarms the injector even when an assertion panics mid-test.
struct Armed;
impl Drop for Armed {
    fn drop(&mut self) {
        faultio::clear();
    }
}

fn tmp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("eva-cim-chaos-{tag}-{}", std::process::id()))
}

fn opts(dir: Option<PathBuf>, workers: usize) -> SweepOptions {
    SweepOptions {
        scale: 2,
        workers,
        cache_dir: dir,
        resume: true,
        ..Default::default()
    }
}

fn points() -> Vec<SweepPoint> {
    cross(
        &["lcs", "km"],
        &[SystemConfig::preset("c1").unwrap()],
        LocalityRule::AnyCache,
    )
}

fn run(o: SweepOptions) -> (Vec<SweepRow>, SweepStats) {
    Coordinator::new(o)
        .run_sweep_with_stats(&points(), &mut NativeBackend)
        .expect("sweep completed")
}

fn dump_rows(rows: &[SweepRow]) -> Vec<String> {
    rows.iter().map(|r| persist::row_to_json(r).dump()).collect()
}

/// The fault-free reference rows (no cache directory at all).
fn plain_rows() -> Vec<String> {
    dump_rows(&run(opts(None, 1)).0)
}

fn clean(dir: &Path) {
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn fault_free_sweeps_are_byte_identical_with_a_clean_fault_ledger() {
    let _g = lock_unpoisoned(&FAULT_LOCK);
    let dir = tmp_dir("clean");
    clean(&dir);
    let plain = plain_rows();

    let (cold, cold_stats) = run(opts(Some(dir.clone()), 2));
    assert_eq!(dump_rows(&cold), plain);
    assert_eq!(cold_stats.io_retries, 0, "fault-free runs never retry");
    assert_eq!(cold_stats.entries_quarantined, 0);
    assert!(!cold_stats.degraded_mode);

    let (warm, warm_stats) = run(opts(Some(dir.clone()), 2));
    assert_eq!(dump_rows(&warm), plain);
    assert_eq!(warm_stats.simulator_runs, 0, "warm resume simulates nothing");
    assert_eq!(warm_stats.io_retries, 0);
    assert_eq!(warm_stats.entries_quarantined, 0);
    assert!(!warm_stats.degraded_mode);
    assert!(
        !dir.join("quarantine").exists(),
        "a clean run must not create the quarantine dir"
    );
    clean(&dir);
}

#[test]
fn transient_faults_are_retried_to_a_byte_identical_result() {
    let _g = lock_unpoisoned(&FAULT_LOCK);
    let dir = tmp_dir("transient");
    clean(&dir);
    let plain = plain_rows();

    let results = dir.join("results.jsonl").display().to_string();
    let artifacts = dir.join("analysis/artifacts.jsonl").display().to_string();
    let guard = Armed;
    faultio::inject(
        FaultPlan::new()
            // results.jsonl sees (at least) open, load-read, two appends;
            // fault the first three, one transient kind each
            .with(FaultSpec::nth(None, &results, 1, FaultKind::Eintr))
            .with(FaultSpec::nth(None, &results, 2, FaultKind::Eagain))
            .with(FaultSpec::nth(None, &results, 3, FaultKind::Eintr))
            .with(FaultSpec::nth(None, &artifacts, 1, FaultKind::Eintr)),
    );
    let (rows, stats) = run(opts(Some(dir.clone()), 1));
    drop(guard);

    assert_eq!(dump_rows(&rows), plain, "retried faults change no byte");
    assert_eq!(stats.io_retries, 4, "each injected transient = one retry");
    assert_eq!(stats.entries_quarantined, 0);
    assert!(!stats.degraded_mode, "recovered faults do not degrade");

    // and the cache the faulted run wrote is a perfectly good warm cache
    let (warm, warm_stats) = run(opts(Some(dir.clone()), 1));
    assert_eq!(dump_rows(&warm), plain);
    assert_eq!(warm_stats.simulator_runs, 0);
    assert_eq!(warm_stats.io_retries, 0);
    clean(&dir);
}

#[test]
fn disk_full_on_result_appends_degrades_without_changing_results() {
    let _g = lock_unpoisoned(&FAULT_LOCK);
    let dir = tmp_dir("enospc");
    clean(&dir);
    let plain = plain_rows();

    let results = dir.join("results.jsonl").display().to_string();
    let guard = Armed;
    faultio::inject(FaultPlan::new().with(FaultSpec::every(
        Some(IoOp::Write),
        &results,
        FaultKind::Enospc,
    )));
    let (rows, stats) = run(opts(Some(dir.clone()), 2));
    drop(guard);

    assert_eq!(dump_rows(&rows), plain, "a full disk loses no result");
    assert!(stats.degraded_mode, "unappendable cache flags degraded mode");
    assert_eq!(stats.io_retries, 0, "ENOSPC is hard, never retried");

    // recovery: with the fault gone the same directory works again
    let (rows2, stats2) = run(opts(Some(dir.clone()), 2));
    assert_eq!(dump_rows(&rows2), plain);
    assert!(!stats2.degraded_mode);
    clean(&dir);
}

#[test]
fn every_seeded_fault_position_is_identical_or_typed_error_never_panic() {
    let _g = lock_unpoisoned(&FAULT_LOCK);
    let plain = plain_rows();

    // walk a hard fault across the first N store operations of the sweep,
    // for each hard kind: whatever lands, the run must either produce the
    // reference bytes or a typed error — and after clearing the fault the
    // same directory must always recover to the reference bytes
    for kind in [FaultKind::Enospc, FaultKind::ShortWrite, FaultKind::Eacces] {
        for n in 1..=12u64 {
            let dir = tmp_dir(&format!("walk-{kind:?}-{n}"));
            clean(&dir);
            let marker = dir.display().to_string();
            let guard = Armed;
            faultio::inject(
                FaultPlan::new().with(FaultSpec::nth(None, &marker, n, kind)),
            );
            let outcome = Coordinator::new(opts(Some(dir.clone()), 1))
                .run_sweep_with_stats(&points(), &mut NativeBackend);
            drop(guard);
            match outcome {
                Ok((rows, _)) => assert_eq!(
                    dump_rows(&rows),
                    plain,
                    "fault {kind:?} at op {n}: completed runs must be \
                     byte-identical"
                ),
                Err(e) => {
                    // a typed error is acceptable; a panic would have
                    // aborted the test before this formats
                    let _ = format!("{e:#}");
                }
            }
            // recovery on the possibly-torn directory: always clean
            let (rows, stats) = run(opts(Some(dir.clone()), 1));
            assert_eq!(
                dump_rows(&rows),
                plain,
                "fault {kind:?} at op {n}: recovery must be byte-identical"
            );
            assert!(
                !stats.degraded_mode,
                "fault {kind:?} at op {n}: recovery run must not degrade"
            );
            clean(&dir);
        }
    }
}

#[test]
fn corrupt_result_lines_quarantine_once_at_every_job_count() {
    let _g = lock_unpoisoned(&FAULT_LOCK);
    let dir = tmp_dir("corrupt-results");
    clean(&dir);
    let plain = plain_rows();
    run(opts(Some(dir.clone()), 1)); // cold populate

    // three flavors of poison: raw garbage, a torn append, a line whose
    // row payload has the wrong shape
    let path = dir.join("results.jsonl");
    let mut text = std::fs::read_to_string(&path).unwrap();
    text.push_str("garbage not json\n");
    text.push_str("{\"key\":\"k-torn\",\"row\":{\"bench\"\n");
    text.push_str("{\"key\":\"zzzz\",\"row\":42}\n");
    std::fs::write(&path, text).unwrap();

    for (i, jobs) in [1usize, 2, 4].into_iter().enumerate() {
        let (rows, stats) = run(opts(Some(dir.clone()), jobs));
        assert_eq!(dump_rows(&rows), plain, "jobs={jobs}");
        assert_eq!(stats.simulator_runs, 0, "good rows still serve warm");
        if i == 0 {
            assert_eq!(
                stats.entries_quarantined, 3,
                "first sighting quarantines each bad line once"
            );
        } else {
            assert_eq!(
                stats.entries_quarantined, 0,
                "jobs={jobs}: already-quarantined lines are not re-counted"
            );
        }
    }
    let qdir = dir.join("quarantine");
    let quarantined: Vec<_> = std::fs::read_dir(&qdir)
        .unwrap()
        .map(|e| e.unwrap().file_name().into_string().unwrap())
        .collect();
    assert_eq!(
        quarantined.iter().filter(|n| n.ends_with(".reason")).count(),
        3,
        "every quarantined line has a reason file: {quarantined:?}"
    );
    clean(&dir);
}

#[test]
fn corrupt_artifact_lines_quarantine_and_never_panic() {
    let _g = lock_unpoisoned(&FAULT_LOCK);
    let dir = tmp_dir("corrupt-artifacts");
    clean(&dir);
    let plain = plain_rows();
    run(opts(Some(dir.clone()), 1)); // cold populate

    // poison a *live* artifact key (a random key would be filtered out
    // before parsing): reuse the last line's key with a wrong-shape body
    let path = dir.join("analysis/artifacts.jsonl");
    let mut text = std::fs::read_to_string(&path).unwrap();
    let last = text.lines().last().unwrap();
    let tail = &last[last.rfind("\"key\":\"").unwrap() + 7..];
    let key = &tail[..tail.find('"').unwrap()];
    text.push_str(&format!("{{\"art\":12,\"key\":\"{key}\"}}\n"));
    std::fs::write(&path, text).unwrap();
    // force the stage-factored artifact path: recompute rows from traces
    std::fs::remove_file(dir.join("results.jsonl")).unwrap();

    let (rows, stats) = run(opts(Some(dir.clone()), 2));
    assert_eq!(dump_rows(&rows), plain, "poisoned artifacts change no byte");
    assert_eq!(stats.entries_quarantined, 1);

    // the second pass sees the same bad line but never re-counts it
    std::fs::remove_file(dir.join("results.jsonl")).unwrap();
    let (rows2, stats2) = run(opts(Some(dir.clone()), 2));
    assert_eq!(dump_rows(&rows2), plain);
    assert_eq!(stats2.entries_quarantined, 0);
    clean(&dir);
}

#[test]
fn corrupt_trace_spills_quarantine_resimulate_and_republish() {
    let _g = lock_unpoisoned(&FAULT_LOCK);
    let dir = tmp_dir("corrupt-trace");
    clean(&dir);
    let plain = plain_rows();
    run(opts(Some(dir.clone()), 1)); // cold populate

    let traces = dir.join("traces");
    let spills: Vec<PathBuf> = std::fs::read_dir(&traces)
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension().is_some_and(|x| x == "bin"))
        .collect();
    assert!(!spills.is_empty(), "the cold sweep spilled traces");
    for p in &spills {
        std::fs::write(p, b"definitely not a v3 trace stream").unwrap();
    }
    // force the replay path: drop rows and artifacts, keep (bad) traces
    std::fs::remove_file(dir.join("results.jsonl")).unwrap();
    std::fs::remove_dir_all(dir.join("analysis")).unwrap();

    let (rows, stats) = run(opts(Some(dir.clone()), 1));
    assert_eq!(dump_rows(&rows), plain, "corrupt spills are misses, not lies");
    assert!(stats.simulator_runs > 0, "the miss re-simulates");
    assert!(stats.entries_quarantined as usize >= spills.len());
    let qdir = dir.join("quarantine");
    assert!(
        std::fs::read_dir(&qdir).unwrap().any(|e| {
            e.unwrap().file_name().to_string_lossy().starts_with("trace-")
        }),
        "the corrupt spill was preserved under quarantine/"
    );

    // the re-simulated traces were re-published: a second stage-factored
    // pass replays from disk without a single simulator run
    std::fs::remove_file(dir.join("results.jsonl")).unwrap();
    std::fs::remove_dir_all(dir.join("analysis")).unwrap();
    let (rows2, stats2) = run(opts(Some(dir.clone()), 1));
    assert_eq!(dump_rows(&rows2), plain);
    assert_eq!(
        stats2.simulator_runs, 0,
        "quarantined spills never re-poison a warm resume"
    );
    assert!(stats2.trace_disk_hits > 0, "replay served from the republished spill");
    assert_eq!(stats2.entries_quarantined, 0);
    clean(&dir);
}

#[test]
fn short_writes_on_spills_degrade_and_never_publish_torn_traces() {
    let _g = lock_unpoisoned(&FAULT_LOCK);
    let dir = tmp_dir("short-spill");
    clean(&dir);
    let plain = plain_rows();

    let guard = Armed;
    // the spill tmp + final paths both contain "trace-"; results.jsonl
    // and artifacts.jsonl do not, so only spill writes tear
    faultio::inject(FaultPlan::new().with(FaultSpec::every(
        Some(IoOp::Write),
        "trace-",
        FaultKind::ShortWrite,
    )));
    let (rows, stats) = run(opts(Some(dir.clone()), 1));
    drop(guard);

    assert_eq!(dump_rows(&rows), plain, "torn spills change no result byte");
    assert!(stats.degraded_mode, "failed spill finalization flags degraded");
    let published: Vec<_> = std::fs::read_dir(dir.join("traces"))
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension().is_some_and(|x| x == "bin"))
        .collect();
    assert!(
        published.is_empty(),
        "a torn spill must never be atomically published: {published:?}"
    );

    // results.jsonl was unaffected: the warm resume is clean and full
    let (rows2, stats2) = run(opts(Some(dir.clone()), 1));
    assert_eq!(dump_rows(&rows2), plain);
    assert_eq!(stats2.simulator_runs, 0);
    assert!(!stats2.degraded_mode);
    clean(&dir);
}

#[test]
fn unwritable_cache_root_degrades_to_in_memory_and_still_answers() {
    let _g = lock_unpoisoned(&FAULT_LOCK);
    // a regular *file* where the cache dir should be: create_dir_all
    // fails even for root (unlike chmod, which root ignores)
    let dir = tmp_dir("notadir");
    clean(&dir);
    std::fs::write(&dir, b"i am a file, not a directory").unwrap();
    let plain = plain_rows();

    let (rows, stats) = run(opts(Some(dir.clone()), 2));
    assert_eq!(dump_rows(&rows), plain, "degraded mode serves full results");
    assert!(stats.degraded_mode, "unusable cache root flags degraded mode");
    assert_eq!(stats.rows_computed, points().len());
    std::fs::remove_file(&dir).ok();
}
