//! PJRT runtime integration: load the AOT artifacts, execute them, and
//! cross-validate against the native Rust mirror — the contract that makes
//! the three-layer architecture trustworthy.
//!
//! Skipped (with a loud message) when `artifacts/` has not been built;
//! `make artifacts && cargo test` exercises the real path.

use eva_cim::analyzer::{analyze, LocalityRule};
use eva_cim::config::{SystemConfig, Technology};
use eva_cim::energy;
use eva_cim::profiler::{evaluate_native_batch, ProfileInputs};
use eva_cim::reshape::reshape;
use eva_cim::runtime::PjrtRuntime;
use eva_cim::sim::{simulate, Limits};
use eva_cim::workloads;

fn runtime() -> Option<PjrtRuntime> {
    match PjrtRuntime::load(&PjrtRuntime::default_dir()) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP: artifacts unavailable ({e:#}) — run `make artifacts`");
            None
        }
    }
}

fn sample_inputs() -> Vec<ProfileInputs> {
    let mut out = Vec::new();
    for (bench, tech) in [
        ("lcs", Technology::SRAM),
        ("m2d", Technology::FEFET),
        ("bfs", Technology::SRAM),
    ] {
        let cfg = SystemConfig::preset("c1").unwrap().with_tech(tech);
        let prog = workloads::build(bench, 2, 5).unwrap();
        let trace = simulate(&prog, &cfg, Limits::default()).unwrap();
        let analysis = analyze(&trace, &cfg, LocalityRule::AnyCache);
        let reshaped = reshape(&trace, &analysis.selection, &cfg);
        out.push(ProfileInputs::new(&cfg, &reshaped));
    }
    out
}

#[test]
fn energy_model_artifact_matches_native_mirror() {
    let Some(mut rt) = runtime() else { return };
    let mut rows = Vec::new();
    for cap_kb in [16.0, 32.0, 64.0, 256.0, 2048.0] {
        for tech in [0.0, 1.0] {
            rows.push([cap_kb * 1024.0, 4.0, 64.0, 4.0, tech, 1.0]);
        }
    }
    let (e_pjrt, l_pjrt) = rt.energy_latency(&rows).unwrap();
    let (e_native, l_native) = energy::array::energy_latency_batch(&rows);
    for i in 0..rows.len() {
        for j in 0..energy::calib::NOPS {
            let rel = |a: f64, b: f64| ((a - b) / b).abs();
            assert!(
                rel(e_pjrt[i][j], e_native[i][j]) < 1e-4,
                "energy row {i} op {j}: pjrt {} native {}",
                e_pjrt[i][j],
                e_native[i][j]
            );
            assert!(rel(l_pjrt[i][j], l_native[i][j]) < 1e-4);
        }
    }
}

#[test]
fn profiler_artifact_matches_native_mirror() {
    let Some(mut rt) = runtime() else { return };
    let inputs = sample_inputs();
    let pjrt = rt.evaluate_profile(&inputs).unwrap();
    let native = evaluate_native_batch(&inputs);
    assert_eq!(pjrt.len(), native.len());
    for (i, (p, n)) in pjrt.iter().zip(&native).enumerate() {
        // f32 kernel vs f64 mirror on ~1e7 pJ magnitudes: 1e-3 relative
        let rel = |a: f64, b: f64| ((a - b) / b.abs().max(1e-9)).abs();
        assert!(rel(p.total_base, n.total_base) < 1e-3, "{i}: total_base");
        assert!(rel(p.total_cim, n.total_cim) < 1e-3, "{i}: total_cim");
        assert!(rel(p.improvement, n.improvement) < 1e-3, "{i}: improvement");
        assert!(rel(p.speedup, n.speedup) < 1e-3, "{i}: speedup");
        for j in 0..energy::calib::NCOMP {
            assert!(
                rel(p.comps_base[j], n.comps_base[j]) < 2e-3
                    || (p.comps_base[j] - n.comps_base[j]).abs() < 1.0,
                "{i}: comp {j}: {} vs {}",
                p.comps_base[j],
                n.comps_base[j]
            );
        }
    }
}

#[test]
fn batching_pads_and_preserves_order() {
    let Some(mut rt) = runtime() else { return };
    // more inputs than one artifact batch, none a multiple of it
    let base = sample_inputs();
    let mut inputs = Vec::new();
    for i in 0..(rt.batch + 3) {
        inputs.push(base[i % base.len()].clone());
    }
    let out = rt.evaluate_profile(&inputs).unwrap();
    assert_eq!(out.len(), inputs.len());
    // identical inputs must give identical outputs wherever they appear
    let a = &out[0];
    let b = &out[base.len()];
    assert!((a.total_base - b.total_base).abs() < 1e-3);
}

#[test]
fn sensitivity_artifact_produces_finite_capacity_gradients() {
    let Some(mut rt) = runtime() else { return };
    let inputs = sample_inputs();
    let (g1, g2) = rt.sensitivity(&inputs).unwrap();
    assert_eq!(g1.len(), inputs.len());
    for (a, b) in g1.iter().zip(&g2) {
        assert!(a.iter().all(|x| x.is_finite()));
        assert!(b.iter().all(|x| x.is_finite()));
        // bigger caches -> more energy per op (finding iii)
        assert!(a[0] > 0.0, "L1 capacity gradient {}", a[0]);
        assert!(b[0] > 0.0, "L2 capacity gradient {}", b[0]);
    }
}

#[test]
fn pjrt_execution_count_reflects_batching() {
    let Some(mut rt) = runtime() else { return };
    let inputs = sample_inputs();
    let before = rt.executions;
    rt.evaluate_profile(&inputs).unwrap();
    assert_eq!(rt.executions, before + 1); // 3 points -> one batched call
}
