//! Differential suite pinning the pre-decoded simulator path to the
//! reference interpreter (PR 8: cold-path speed pass).
//!
//! 1. **Opcode coverage** — a deterministic program committing all 48
//!    committable opcodes (every opcode but `halt`), including div/rem by
//!    zero, `i32::MIN / -1`, shift amounts past 31, NaN-producing float
//!    ops, byte vs word memory, all six conditional branches both taken
//!    and not-taken, and data-dependent `jalr` targets — byte-identical
//!    commit streams, `PipeStats`, `MemStats` and summaries on both paths.
//! 2. **Randomized programs** — a proptest corpus of random ALU/memory/
//!    control-flow mixes with bounded loops, run through both paths and
//!    compared record-for-record.
//! 3. **Fault equivalence** — out-of-bounds and unaligned accesses fault
//!    at the same point with the same `SimError` and the same committed
//!    prefix; `RanOffEnd` and `MaxInstructions` stops also agree.
//! 4. **Report equivalence** — a full cold sweep re-run with the
//!    [`force_reference_path`] seam set renders byte-identical Report
//!    output in all three formats, proving no cache key, ledger counter
//!    or rendered byte depends on which path simulated.
//!
//! The same discipline as `replay_parallel.rs` pins for the warm path:
//! the fast path must be *invisible* except in wall-clock.

use eva_cim::api::{BackendSel, Evaluation};
use eva_cim::asm::{Asm, Program};
use eva_cim::config::{CimLevels, SystemConfig, Technology};
use eva_cim::isa::{Opcode, NUM_OPCODES};
use eva_cim::probes::{CollectSink, StopReason, Trace};
use eva_cim::sim::decode::simulate_decoded_into;
use eva_cim::sim::{
    force_reference_path, simulate_reference_into, Limits, SimError,
};
use eva_cim::util::proptest::check;
use eva_cim::util::Rng;

/// Run one program through both paths and return the two materialized
/// traces (uses the explicit entry points, so the process-global
/// `force_reference_path` seam cannot interfere with parallel tests).
fn run_both(
    prog: &Program,
    cfg: &SystemConfig,
    limits: Limits,
) -> (Result<Trace, SimError>, Result<Trace, SimError>) {
    let run = |reference: bool| {
        let mut sink = CollectSink::default();
        let res = if reference {
            simulate_reference_into(prog, cfg, limits, &mut sink)
        } else {
            simulate_decoded_into(prog, cfg, limits, &mut sink)
        };
        res.map(|summary| Trace::from_parts(summary, sink.ciq))
    };
    (run(true), run(false))
}

/// Both paths succeed and agree on every byte of the trace.
fn assert_identical(prog: &Program, cfg: &SystemConfig, limits: Limits) -> Trace {
    let (reference, decoded) = run_both(prog, cfg, limits);
    let reference = reference.expect("reference path faulted");
    let decoded = decoded.expect("decoded path faulted");
    assert_eq!(
        reference.summary(),
        decoded.summary(),
        "summaries diverge on {}",
        prog.name
    );
    assert_eq!(
        reference.ciq, decoded.ciq,
        "commit streams diverge on {}",
        prog.name
    );
    assert_eq!(reference, decoded);
    reference
}

/// A deterministic program committing every opcode except `halt`,
/// deliberately hitting the integer/float corner cases the decode table
/// must preserve exactly.
fn all_opcode_program() -> Program {
    let mut a = Asm::new("all-ops");
    let buf = a.data.alloc_i32("buf", &[5, -3, 0x1234, -100, 0, 77]);
    let fbuf = a.data.alloc_f32("fbuf", &[1.5, -2.25, 0.0, 3.75]);
    let out = a.data.alloc_i32("out", &[0; 8]);

    a.li(1, buf as i32);
    a.li(2, fbuf as i32);
    a.li(10, out as i32);

    // loads (word, sign-extended byte, float)
    a.lw(3, 1, 0); // 5
    a.lw(4, 1, 4); // -3
    a.lb(5, 1, 8); // 0x34
    a.lb(5, 1, 7); // 0xff of -3 -> sign-extends to -1
    a.flw(0, 2, 0); // 1.5
    a.flw(1, 2, 4); // -2.25
    a.flw(2, 2, 8); // 0.0

    // integer reg-reg, including division corners and shift masking
    a.add(6, 3, 4);
    a.sub(6, 6, 3);
    a.and(7, 3, 4);
    a.or(7, 7, 3);
    a.xor(7, 7, 4);
    a.sll(8, 3, 4); // shift by -3: amount masks to 29
    a.srl(8, 4, 3); // logical shift of a negative value
    a.sra(8, 4, 3);
    a.slt(9, 4, 3);
    a.sltu(9, 3, 4); // 5 <u 0xfffffffd
    a.mul(11, 3, 4);
    a.lw(13, 1, 16); // 0
    a.div(12, 3, 13); // divide by zero -> -1
    a.rem(12, 4, 13); // rem by zero -> rs1
    a.div(12, 3, 4);
    a.rem(12, 3, 4);
    a.li(15, i32::MIN);
    a.li(16, -1);
    a.div(17, 15, 16); // i32::MIN / -1 wraps
    a.rem(17, 15, 16);

    // integer reg-imm, including immediate shift masking
    a.addi(18, 3, 100);
    a.andi(18, 18, 0xff);
    a.ori(18, 18, 0x10);
    a.xori(18, 18, -1);
    a.slli(19, 3, 35); // masks to 3
    a.srli(19, 4, 1);
    a.srai(19, 4, 1);
    a.slti(20, 4, 7);
    a.lui(21, 0x5a5a);

    // floating point, including inf and NaN
    a.fadd(3, 0, 1);
    a.fsub(4, 0, 1);
    a.fmul(5, 0, 1);
    a.fdiv(6, 0, 2); // 1.5 / 0.0 = +inf
    a.fdiv(7, 2, 2); // 0.0 / 0.0 = NaN
    a.fmin(8, 0, 1);
    a.fmax(9, 0, 1);
    a.feq(22, 0, 0);
    a.feq(22, 7, 7); // NaN == NaN -> 0
    a.flt(22, 1, 0);
    a.fcvt_w_s(23, 1); // -2.25 -> -2
    a.fcvt_s_w(10, 4);
    a.fmv(11, 10);

    // stores (word, byte, float)
    a.sw(6, 10, 0);
    a.sb(5, 10, 4);
    a.fsw(11, 10, 8);

    // all six conditional branches, taken and not-taken
    let l1 = a.label("l1");
    a.beq(3, 3, l1); // taken
    a.nop();
    a.bind(l1);
    let l2 = a.label("l2");
    a.bne(3, 4, l2); // taken
    a.nop();
    a.bind(l2);
    let l3 = a.label("l3");
    a.blt(4, 3, l3); // taken
    a.nop();
    a.bind(l3);
    let l4 = a.label("l4");
    a.bge(4, 3, l4); // not taken: falls into the nop
    a.nop();
    a.bind(l4);
    let l5 = a.label("l5");
    a.bltu(3, 4, l5); // taken (-3 is huge unsigned)
    a.nop();
    a.bind(l5);
    let l6 = a.label("l6");
    a.bgeu(3, 4, l6); // not taken
    a.nop();
    a.bind(l6);

    // a predictable backward loop (predictor warm-up + mispredict at exit)
    let top = a.label("top");
    a.li(25, 0);
    a.li(26, 50);
    a.bind(top);
    a.addi(25, 25, 1);
    a.bne(25, 26, top);

    // jumps: jal with a live link, jalr with a data-dependent target,
    // and the plain jump pseudo (jal r0)
    let fwd = a.label("fwd");
    a.jal(27, fwd);
    a.nop(); // skipped
    a.bind(fwd);
    let t = a.len() as i32 + 3; // li, jalr, dead nop, then the target
    a.li(28, t);
    a.jalr(29, 28);
    a.nop(); // skipped
    let end = a.label("end");
    a.jump(end);
    a.nop(); // skipped
    a.bind(end);
    a.nop(); // a committed nop
    a.halt();
    a.assemble()
}

#[test]
fn all_opcodes_byte_identical() {
    let prog = all_opcode_program();
    for preset in ["c1", "c2"] {
        let cfg = SystemConfig::preset(preset).unwrap();
        let t = assert_identical(&prog, &cfg, Limits::default());
        assert_eq!(t.stop, StopReason::Halt);

        // every opcode except halt commits at least once
        let mut seen = [false; NUM_OPCODES as usize];
        for is in &t.ciq {
            seen[is.instr.op as u8 as usize] = true;
        }
        for x in 0..NUM_OPCODES {
            let op = Opcode::from_u8(x).unwrap();
            if op == Opcode::Halt {
                assert!(!seen[x as usize], "halt must never commit");
            } else {
                assert!(seen[x as usize], "{op:?} never committed");
            }
        }
        // the corner cases actually exercised the predictor and both
        // memory classes
        assert!(t.pipe.bpred_lookups > 50);
        assert!(t.pipe.lsq_reads >= 7 && t.pipe.lsq_writes >= 3);
    }
}

/// Random ALU/memory/control-flow mix.  Register discipline: r1/r2/r10
/// hold the data/float/out base addresses and are never overwritten;
/// r3..r9 are scratch; r14/r17 serve the jalr epilogue; r15/r16 drive the
/// bounded loop.  All memory offsets stay inside the allocated buffers so
/// the only faults are the ones the dedicated fault test injects.
fn random_program(rng: &mut Rng, size: u32) -> Program {
    let n_ops = 30 + (size as usize % 120);
    let mut a = Asm::new("diff-rand");
    let words: Vec<i32> =
        (0..16).map(|_| rng.next_u32() as i32 / 7).collect();
    let buf = a.data.alloc_i32("buf", &words);
    let fvals: Vec<f32> =
        (0..8).map(|_| (rng.gen_f64() * 100.0 - 50.0) as f32).collect();
    let fbuf = a.data.alloc_f32("fbuf", &fvals);
    let out = a.data.alloc_i32("out", &[0; 16]);

    a.li(1, buf as i32);
    a.li(2, fbuf as i32);
    a.li(10, out as i32);
    for r in 3..=9u8 {
        a.lw(r, 1, ((r as i32 - 3) % 16) * 4);
    }
    for f in 0..6u8 {
        a.flw(f, 2, ((f as i32) % 8) * 4);
    }

    for _ in 0..n_ops {
        let rd = 3 + rng.gen_range(7) as u8;
        let rs1 = 3 + rng.gen_range(7) as u8;
        let rs2 = 3 + rng.gen_range(7) as u8;
        match rng.gen_range(14) {
            0 => {
                a.add(rd, rs1, rs2);
            }
            1 => {
                a.sub(rd, rs1, rs2);
            }
            2 => {
                a.mul(rd, rs1, rs2);
            }
            3 => {
                // random divisor values, occasionally zero
                a.div(rd, rs1, rs2);
            }
            4 => {
                a.rem(rd, rs1, rs2);
            }
            5 => {
                // random shift amounts, frequently past 31
                a.sll(rd, rs1, rs2);
            }
            6 => {
                a.sra(rd, rs1, rs2);
            }
            7 => {
                a.xori(rd, rs1, rng.next_u32() as i32);
            }
            8 => {
                a.lw(rd, 1, (rng.gen_range(16) as i32) * 4);
            }
            9 => {
                a.lb(rd, 1, rng.gen_range(64) as i32);
            }
            10 => {
                a.sw(rs1, 10, (rng.gen_range(16) as i32) * 4);
            }
            11 => {
                a.sb(rs1, 10, rng.gen_range(64) as i32);
            }
            12 => {
                let fd = rng.gen_range(6) as u8;
                let f1 = rng.gen_range(6) as u8;
                let f2 = rng.gen_range(6) as u8;
                match rng.gen_range(5) {
                    0 => {
                        a.fadd(fd, f1, f2);
                    }
                    1 => {
                        a.fsub(fd, f1, f2);
                    }
                    2 => {
                        a.fmul(fd, f1, f2);
                    }
                    3 => {
                        // random operands, occasionally 0/0 -> NaN
                        a.fdiv(fd, f1, f2);
                    }
                    _ => {
                        a.fcvt_w_s(rd, f1);
                    }
                }
            }
            _ => {
                // data-dependent forward branch over 1..=3 fillers
                let l = a.label("skip");
                match rng.gen_range(4) {
                    0 => {
                        a.beq(rs1, rs2, l);
                    }
                    1 => {
                        a.bne(rs1, rs2, l);
                    }
                    2 => {
                        a.blt(rs1, rs2, l);
                    }
                    _ => {
                        a.bgeu(rs1, rs2, l);
                    }
                }
                for _ in 0..(1 + rng.gen_range(3)) {
                    a.addi(rd, rd, 1);
                }
                a.bind(l);
            }
        }
    }

    // bounded backward loop with mixed memory traffic
    let top = a.label("top");
    a.li(15, 0);
    a.li(16, 20 + rng.gen_range(40) as i32);
    a.bind(top);
    a.addi(15, 15, 1);
    a.lw(9, 1, (rng.gen_range(16) as i32) * 4);
    a.bne(15, 16, top);

    // jalr epilogue with a data-dependent target
    let t = a.len() as i32 + 3;
    a.li(14, t);
    a.jalr(17, 14);
    a.nop(); // skipped
    a.halt();
    a.assemble()
}

#[test]
fn prop_random_programs_byte_identical() {
    check(
        "sim-differential",
        60,
        random_program,
        |prog| {
            for preset in ["c1", "c2"] {
                let cfg = SystemConfig::preset(preset).unwrap();
                let (reference, decoded) =
                    run_both(prog, &cfg, Limits::default());
                let reference = reference.map_err(|e| e.to_string())?;
                let decoded = decoded.map_err(|e| e.to_string())?;
                if reference.stop != StopReason::Halt {
                    return Err(format!("unexpected stop {:?}", reference.stop));
                }
                if reference != decoded {
                    // report the first diverging record for debuggability
                    for (r, d) in reference.ciq.iter().zip(decoded.ciq.iter())
                    {
                        if r != d {
                            return Err(format!(
                                "first divergence at seq {}: {:?} vs {:?}",
                                r.seq, r, d
                            ));
                        }
                    }
                    return Err(format!(
                        "summaries diverge: {:?} vs {:?}",
                        reference.summary(),
                        decoded.summary()
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn faults_and_stops_identical() {
    let cfg = SystemConfig::default();

    // out-of-bounds load faults at the same point with the same error
    let mut a = Asm::new("oob");
    a.li(1, 0x7fff_fff0u32 as i32);
    a.addi(3, 0, 7);
    a.lw(2, 1, 0);
    a.halt();
    let prog = a.assemble();
    let (r, d) = run_both(&prog, &cfg, Limits::default());
    let (re, de) = (r.unwrap_err(), d.unwrap_err());
    assert_eq!(re, de);
    assert_eq!(re.pc, 2);

    // unaligned word access
    let mut a = Asm::new("unaligned");
    a.li(1, 2);
    a.sw(1, 1, 0);
    a.halt();
    let prog = a.assemble();
    let (r, d) = run_both(&prog, &cfg, Limits::default());
    assert_eq!(r.unwrap_err(), d.unwrap_err());

    // the committed prefix before a fault is identical too
    let mut a = Asm::new("prefix");
    let buf = a.data.alloc_i32("buf", &[1, 2, 3]);
    a.li(1, buf as i32);
    a.lw(3, 1, 0);
    a.add(3, 3, 3);
    a.li(2, 0x7fff_fff0u32 as i32);
    a.lw(4, 2, 0); // faults
    a.halt();
    let prog = a.assemble();
    let mut ref_sink = CollectSink::default();
    let mut dec_sink = CollectSink::default();
    let re = simulate_reference_into(&prog, &cfg, Limits::default(), &mut ref_sink)
        .unwrap_err();
    let de =
        simulate_decoded_into(&prog, &cfg, Limits::default(), &mut dec_sink)
            .unwrap_err();
    assert_eq!(re, de);
    assert_eq!(ref_sink.ciq.len(), 4); // li, lw, add, li committed first
    assert_eq!(ref_sink.ciq, dec_sink.ciq);

    // running off the end of the text segment
    let mut a = Asm::new("off-end");
    a.addi(3, 0, 1);
    a.addi(3, 3, 1);
    let prog = a.assemble();
    let t = assert_identical(&prog, &cfg, Limits::default());
    assert_eq!(t.stop, StopReason::RanOffEnd);

    // instruction-budget stop
    let mut a = Asm::new("budget");
    let top = a.label("top");
    a.bind(top);
    a.addi(3, 3, 1);
    a.jump(top);
    let prog = a.assemble();
    let t =
        assert_identical(&prog, &cfg, Limits { max_instructions: 500 });
    assert_eq!(t.stop, StopReason::MaxInstructions);
    assert_eq!(t.committed, 500);
}

/// The whole stack — coordinator grouping, stage caches, energy fold,
/// report rendering — produces byte-identical output whichever simulator
/// path ran.  Uses the process-global [`force_reference_path`] seam; this
/// is the only test in this binary that touches it, and it restores the
/// default even on failure paths before asserting.
#[test]
fn cold_sweep_reports_identical_on_both_paths() {
    let eval = || {
        Evaluation::new()
            .bench("lcs")
            .preset("c1")
            .techs(&[Technology::SRAM, Technology::FEFET])
            .cim_variants(&[CimLevels::L1Only, CimLevels::Both])
            .scale(2)
            .seed(11)
            .jobs(2)
            .backend(BackendSel::Native)
    };
    let decoded = eval().run();
    force_reference_path(true);
    let reference = eval().run();
    force_reference_path(false);

    let decoded = decoded.expect("decoded sweep");
    let reference = reference.expect("reference sweep");
    assert_eq!(decoded.render_json(), reference.render_json());
    assert_eq!(decoded.render_table(), reference.render_table());
    assert_eq!(decoded.render_csv(), reference.render_csv());
}
