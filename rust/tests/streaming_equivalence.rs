//! The streaming/batch equivalence guard.
//!
//! The online analyzer (`analyzer::stream`) replaces the materialize-then-
//! batch-analyze pipeline; this suite pins the contract that makes the
//! migration safe: on randomized programs, every `LocalityRule` and every
//! CiM placement, the streaming path produces **byte-identical** candidate
//! sets, rejection counters, MACR, IDG statistics and `Reshaped` counter
//! vectors to the legacy batch path (`analyze_batch`), whether records
//! arrive from a materialized CIQ, the sequential in-thread stream, or the
//! pipelined simulator-thread stream.

use eva_cim::analyzer::{
    analysis_from_stream, analyze, analyze_batch, Analysis, CandidateRecord,
    CandidateSink, CollectCandidates, LocalityRule, OnlineAnalyzer,
};
use eva_cim::asm::Asm;
use eva_cim::config::{CimLevels, SystemConfig};
use eva_cim::pipeline::{run_pipelined, run_streaming};
use eva_cim::probes::Trace;
use eva_cim::reshape::{reshape, reshape_from_deltas, DeltaSink, Reshaped};
use eva_cim::sim::{simulate, Limits};
use eva_cim::util::proptest::check;
use eva_cim::util::Rng;

/// Candidates + reshape deltas from one streaming pass.
#[derive(Default)]
struct BothSinks {
    cands: CollectCandidates,
    deltas: DeltaSink,
}

impl CandidateSink for BothSinks {
    fn on_candidate(&mut self, rec: CandidateRecord) {
        // fold by reference first, then let the collector take ownership
        self.deltas.fold(&rec);
        self.cands.on_candidate(rec);
    }
}

/// Generate a random but always-terminating program that stresses the
/// claim structure: the canonical convertible patterns, *shared* loads
/// (one load feeding two trees), diamonds (one node feeding two parents
/// of one tree), eligibility breakers, and a loop wrapper so registers
/// are rewritten across iterations.
fn random_program(rng: &mut Rng, size: u32) -> Asm {
    let mut a = Asm::new("equiv");
    let words = 64 + 8 * size;
    let init: Vec<i32> = (0..words).map(|i| i as i32 * 3 + 1).collect();
    let buf = a.data.alloc_i32("buf", &init);
    a.li(1, buf as i32);
    for k in 0..4 {
        a.lw(9, 1, k * 64); // warm a few lines into L1
    }
    let iters = 1 + rng.gen_range(2) as i32; // 1..=2 loop iterations
    a.li(10, 0);
    a.li(11, iters);
    let top = a.label("top");
    a.bind(top);
    let blocks = 2 + size % 8;
    for b in 0..blocks {
        let off = ((b * 12) % (words - 8)) as i32 * 4;
        match rng.gen_range(8) {
            0 => {
                // canonical load-load-op-store
                a.lw(2, 1, off);
                a.lw(3, 1, off + 4);
                match rng.gen_range(4) {
                    0 => a.add(4, 2, 3),
                    1 => a.and(4, 2, 3),
                    2 => a.or(4, 2, 3),
                    _ => a.xor(4, 2, 3),
                };
                a.sw(4, 1, off + 8);
            }
            1 => {
                // imm variant
                a.lw(2, 1, off);
                a.addi(4, 2, rng.gen_range(100) as i32);
                a.sw(4, 1, off);
            }
            2 => {
                // non-convertible mul chain
                a.lw(2, 1, off);
                a.mul(4, 2, 2);
                a.sw(4, 1, off + 4);
            }
            3 => {
                // chained reduction (multi-node tree)
                a.lw(2, 1, off);
                a.lw(3, 1, off + 4);
                a.add(5, 2, 3);
                a.lw(6, 1, off + 8);
                a.add(5, 5, 6);
                a.sw(5, 1, off + 12);
            }
            4 => {
                // shared load: one load feeds two separate trees — the
                // deeper tree must claim it, the earlier sees it shared
                a.lw(2, 1, off);
                a.addi(4, 2, 1);
                a.sw(4, 1, off + 4);
                a.addi(5, 2, 2);
                a.sw(5, 1, off + 8);
            }
            5 => {
                // diamond: one node feeds two parents of the same tree
                a.lw(2, 1, off);
                a.addi(3, 2, 1); // x
                a.addi(4, 3, 2); // a = x + 2
                a.addi(5, 3, 3); // b = x + 3
                a.add(6, 4, 5); // root sees x twice through a and b
                a.sw(6, 1, off + 4);
            }
            6 => {
                // scalar-only block (no loads -> rejected_no_loads)
                a.addi(7, 7, 1);
                a.slli(8, 7, 2);
            }
            _ => {
                // store of a loaded value (copy, not convertible)
                a.lw(2, 1, off);
                a.sw(2, 1, off + 16);
            }
        }
    }
    a.addi(10, 10, 1);
    a.bne(10, 11, top);
    a.halt();
    a
}

fn stream_over(
    trace: &Trace,
    cfg: &SystemConfig,
    rule: LocalityRule,
) -> (Analysis, Reshaped) {
    let mut oa = OnlineAnalyzer::new(cfg.cim_levels, rule, BothSinks::default());
    for is in &trace.ciq {
        oa.push(is);
    }
    let (out, sinks) = oa.finish();
    let reshaped = reshape_from_deltas(&trace.summary(), &sinks.deltas, cfg);
    (analysis_from_stream(out, sinks.cands), reshaped)
}

fn assert_equivalent(tag: &str, batch: &Analysis, streamed: &Analysis) -> Result<(), String> {
    if streamed.selection.candidates != batch.selection.candidates {
        return Err(format!(
            "{tag}: candidates diverge\nbatch:  {:?}\nstream: {:?}",
            batch.selection.candidates, streamed.selection.candidates
        ));
    }
    if streamed.selection.rejected_locality != batch.selection.rejected_locality
        || streamed.selection.rejected_no_loads != batch.selection.rejected_no_loads
        || streamed.selection.rejected_dram != batch.selection.rejected_dram
    {
        return Err(format!(
            "{tag}: rejection counters diverge: batch ({}, {}, {}) vs stream ({}, {}, {})",
            batch.selection.rejected_locality,
            batch.selection.rejected_no_loads,
            batch.selection.rejected_dram,
            streamed.selection.rejected_locality,
            streamed.selection.rejected_no_loads,
            streamed.selection.rejected_dram
        ));
    }
    if streamed.macr != batch.macr {
        return Err(format!(
            "{tag}: macr diverges: {:?} vs {:?}",
            batch.macr, streamed.macr
        ));
    }
    if streamed.idg_nodes != batch.idg_nodes {
        return Err(format!(
            "{tag}: idg counts diverge: {:?} vs {:?}",
            batch.idg_nodes, streamed.idg_nodes
        ));
    }
    Ok(())
}

fn assert_reshape_equal(tag: &str, batch: &Reshaped, streamed: &Reshaped) -> Result<(), String> {
    if streamed.base != batch.base {
        return Err(format!("{tag}: base counters diverge"));
    }
    if streamed.cim != batch.cim {
        return Err(format!(
            "{tag}: cim counters diverge\nbatch:  {:?}\nstream: {:?}",
            batch.cim, streamed.cim
        ));
    }
    if streamed.perf != batch.perf {
        return Err(format!(
            "{tag}: perf vectors diverge: {:?} vs {:?}",
            batch.perf, streamed.perf
        ));
    }
    if streamed.removed != batch.removed || streamed.cim_op_count != batch.cim_op_count {
        return Err(format!(
            "{tag}: removed/cim_ops diverge: ({}, {}) vs ({}, {})",
            batch.removed, batch.cim_op_count, streamed.removed, streamed.cim_op_count
        ));
    }
    Ok(())
}

const RULES: [LocalityRule; 3] = [
    LocalityRule::AnyCache,
    LocalityRule::SameLevel,
    LocalityRule::SameBank,
];

#[test]
fn prop_streaming_matches_batch_on_random_programs() {
    check(
        "streaming-equals-batch",
        40,
        |rng, size| {
            let cfg = SystemConfig::preset("c1").unwrap();
            let prog = random_program(rng, size).assemble();
            simulate(&prog, &cfg, Limits::default()).unwrap()
        },
        |trace| {
            for cim in [
                CimLevels::Both,
                CimLevels::L1Only,
                CimLevels::L2Only,
                CimLevels::None,
            ] {
                let mut cfg = SystemConfig::preset("c1").unwrap();
                cfg.cim_levels = cim;
                for rule in RULES {
                    let tag = format!("cim={cim:?} rule={rule:?}");
                    let batch = analyze_batch(trace, &cfg, rule);
                    let (streamed, r_stream) = stream_over(trace, &cfg, rule);
                    assert_equivalent(&tag, &batch, &streamed)?;
                    let r_batch = reshape(trace, &batch.selection, &cfg);
                    assert_reshape_equal(&tag, &r_batch, &r_stream)?;
                    // the public batch API must be the same adapter
                    let public = analyze(trace, &cfg, rule);
                    assert_equivalent(&format!("{tag} (analyze)"), &batch, &public)?;
                }
            }
            Ok(())
        },
    );
}

#[test]
fn pipelined_and_sequential_streams_match_batch_on_workloads() {
    let cfg = SystemConfig::preset("c1").unwrap();
    for bench in ["lcs", "km", "bfs"] {
        let prog = eva_cim::workloads::build(bench, 2, 7).unwrap();
        let trace = simulate(&prog, &cfg, Limits::default()).unwrap();
        for rule in RULES {
            let batch = analyze_batch(&trace, &cfg, rule);
            let r_batch = reshape(&trace, &batch.selection, &cfg);

            let (summary, out, sinks) = run_pipelined(
                &prog,
                &cfg,
                Limits::default(),
                rule,
                BothSinks::default(),
                None,
            )
            .unwrap();
            assert_eq!(summary.committed, trace.committed, "{bench}");
            assert_eq!(summary.cycles, trace.cycles, "{bench}");
            let r_pipe = reshape_from_deltas(&summary, &sinks.deltas, &cfg);
            let piped = analysis_from_stream(out, sinks.cands);
            assert_equivalent(&format!("{bench} pipelined"), &batch, &piped).unwrap();
            assert_reshape_equal(&format!("{bench} pipelined"), &r_batch, &r_pipe)
                .unwrap();

            let (s2, out2, sinks2) = run_streaming(
                &prog,
                &cfg,
                Limits::default(),
                rule,
                BothSinks::default(),
            )
            .unwrap();
            assert_eq!(s2.committed, trace.committed, "{bench}");
            let seq = analysis_from_stream(out2, sinks2.cands);
            assert_equivalent(&format!("{bench} sequential"), &batch, &seq).unwrap();
        }
    }
}
