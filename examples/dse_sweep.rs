//! Design-space exploration: the paper's three questions answered in one
//! sweep — is the program CiM-favorable, which cache level should host the
//! CiM arrays, and which technology wins?  Exercises the coordinator's
//! worker pool on 17 benchmarks across every *registered* technology
//! (4 built-ins unless more are registered — see `eva-cim explore` and
//! `energy::device` for the registry).
//!
//! Run: `cargo run --release --example dse_sweep`

use eva_cim::analyzer::LocalityRule;
use eva_cim::config::{CimLevels, SystemConfig, Technology};
use eva_cim::coordinator::{cross, Coordinator, SweepOptions};
use eva_cim::runtime::{Backend, NativeBackend};
use eva_cim::util::TextTable;
use eva_cim::workloads;

fn main() -> anyhow::Result<()> {
    let mut configs = Vec::new();
    for preset in ["c1", "c3"] {
        for tech in Technology::all() {
            for cim in [CimLevels::L1Only, CimLevels::Both] {
                let mut c = SystemConfig::preset(preset)
                    .unwrap()
                    .with_tech(tech)
                    .with_cim(cim);
                c.name = format!("{preset}-{}-{}", tech.name(), cim.name());
                configs.push(c);
            }
        }
    }
    let benches: Vec<&str> = workloads::NAMES.to_vec();
    let points = cross(&benches, &configs, LocalityRule::AnyCache);
    println!("sweeping {} design points...", points.len());

    // registry technologies beyond SRAM/FeFET (rram, stt-mram) are outside
    // the frozen AOT tech table, so this all-registered sweep always runs
    // on the native mirror; see technology_explorer.rs for the PJRT path
    let mut backend = NativeBackend;
    let t0 = std::time::Instant::now();
    let rows = Coordinator::new(SweepOptions::default())
        .run_sweep(&points, &mut backend)?;
    println!(
        "{} points in {:.1}s on backend '{}'",
        rows.len(),
        t0.elapsed().as_secs_f64(),
        backend.name()
    );

    // best configuration per benchmark (max energy improvement)
    let mut t = TextTable::new(
        "best design point per benchmark",
        &["bench", "config", "E-impr", "speedup", "MACR"],
    );
    for b in &benches {
        if let Some(best) = rows
            .iter()
            .filter(|r| r.bench == *b)
            .max_by(|x, y| x.result.improvement.total_cmp(&y.result.improvement))
        {
            t.row(vec![
                workloads::display_name(b).into(),
                best.config_name.clone(),
                format!("{:.2}", best.result.improvement),
                format!("{:.2}", best.result.speedup),
                format!("{:.0}%", best.macr.ratio() * 100.0),
            ]);
        }
    }
    println!("{}", t.render());
    Ok(())
}
