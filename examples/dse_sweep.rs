//! Design-space exploration: the paper's three questions answered in one
//! sweep — is the program CiM-favorable, which cache level should host the
//! CiM arrays, and which technology wins?  Exercises the facade's variant
//! crossings (presets × every registered technology × CiM placements) and
//! post-processes the raw rows into a best-point-per-benchmark table.
//!
//! Run: `cargo run --release --example dse_sweep`

use eva_cim::api::{BackendSel, Cell, Evaluation, Report, Section};
use eva_cim::config::{CimLevels, Technology};
use eva_cim::workloads;

fn main() -> anyhow::Result<()> {
    // c1/c3 × all registered technologies × {L1-only, L1+L2}: variant
    // names compose as "{preset}-{tech}-{cim}".  Registry technologies
    // beyond SRAM/FeFET are outside the frozen AOT tech table, so this
    // all-registered sweep runs on the native mirror.
    let ev = Evaluation::new()
        .presets(&["c1", "c3"])
        .techs(&Technology::all())
        .cim_variants(&[CimLevels::L1Only, CimLevels::Both])
        .backend(BackendSel::Native);
    let sweep = ev.rows()?;
    println!(
        "{} points in {:.1}s on backend '{}'",
        sweep.rows.len(),
        sweep.elapsed_secs,
        sweep.backend
    );

    // best configuration per benchmark (max energy improvement)
    let mut s = Section::new(
        "best design point per benchmark",
        &["bench", "config", "E-impr", "speedup", "MACR"],
    );
    for b in workloads::NAMES {
        if let Some(best) = sweep
            .rows
            .iter()
            .filter(|r| r.bench == b)
            .max_by(|x, y| x.result.improvement.total_cmp(&y.result.improvement))
        {
            s.row(vec![
                Cell::str(workloads::display_name(b)),
                Cell::str(best.config_name.as_str()),
                Cell::num(best.result.improvement, 2),
                Cell::num(best.result.speedup, 2),
                Cell::pct(best.macr.ratio(), 0),
            ]);
        }
    }
    let report = Report::new("dse").with_section(s);
    print!("{}", report.render_table());
    Ok(())
}
