//! Quickstart: the full Eva-CiM pipeline on one benchmark, end to end —
//! simulate → IDG analysis → trace reshaping → AOT'd profiler on PJRT
//! (falls back to the native mirror when `make artifacts` hasn't run).
//!
//! Run: `cargo run --release --example quickstart`

use eva_cim::analyzer::{analyze, LocalityRule};
use eva_cim::config::SystemConfig;
use eva_cim::profiler::ProfileInputs;
use eva_cim::reshape::reshape;
use eva_cim::runtime::{best_backend, PjrtRuntime};
use eva_cim::sim::{simulate, Limits};
use eva_cim::workloads;

fn main() -> anyhow::Result<()> {
    // 1. pick a system: 32kB/4-way L1 + 256kB/8-way L2, SRAM CiM in both
    let cfg = SystemConfig::preset("c1").unwrap();

    // 2. build a workload and run it on the cycle-level simulator
    let prog = workloads::build("lcs", 0, 42).unwrap();
    let trace = simulate(&prog, &cfg, Limits::default())?;
    println!(
        "simulated {}: {} instructions, {} cycles (CPI {:.2})",
        trace.program, trace.committed, trace.cycles, trace.cpi()
    );

    // 3. mine the committed instruction queue for offloading candidates
    let analysis = analyze(&trace, &cfg, LocalityRule::AnyCache);
    println!(
        "IDG: {} nodes ({} eligible) -> {} candidates, MACR {:.1}%",
        analysis.idg_nodes.0,
        analysis.idg_nodes.1,
        analysis.selection.candidates.len(),
        analysis.macr.ratio() * 100.0
    );

    // 4. reshape the trace: offloaded work leaves the CPU, CiM ops appear
    let reshaped = reshape(&trace, &analysis.selection, &cfg);
    println!(
        "reshaped: {} instructions offloaded into {} CiM ops",
        reshaped.removed, reshaped.cim_op_count
    );

    // 5. profile through the AOT'd JAX graph on the PJRT CPU client
    let mut backend = best_backend(&PjrtRuntime::default_dir());
    let res = backend
        .evaluate_batch(&[ProfileInputs::new(&cfg, &reshaped)])?
        .remove(0);
    println!("backend: {}", backend.name());
    println!(
        "energy: {:.2} uJ -> {:.2} uJ  ({:.2}x improvement)",
        res.total_base / 1e6,
        res.total_cim / 1e6,
        res.improvement
    );
    println!(
        "speedup: {:.2}x   breakdown: processor {:.2} / caches {:.2}",
        res.speedup, res.ratio_proc, res.ratio_cache
    );
    Ok(())
}
