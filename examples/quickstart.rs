//! Quickstart: the unified evaluation facade, end to end — one benchmark
//! profiled through the full pipeline (sim → IDG analysis → reshape →
//! profiler), then a small sweep, each returned as a structured `Report`
//! that renders as text, CSV or canonical JSON from the same value.
//!
//! Run: `cargo run --release --example quickstart`

use eva_cim::api::Evaluation;

fn main() -> anyhow::Result<()> {
    // 1. one benchmark on one configuration: the whole pipeline is behind
    //    a single builder call (backend auto-selected: PJRT when the AOT
    //    artifacts exist, the native f64 mirror otherwise)
    let profile = Evaluation::new().bench("lcs").preset("c1").single()?;
    print!("{}", profile.render_table());

    // 2. a benches × presets sweep through the coordinator's cached path;
    //    add .cache_dir("...").resume(true) to make reruns warm-start
    let sweep = Evaluation::new()
        .benches(&["lcs", "km"])
        .presets(&["c1", "c2"])
        .run()?;
    print!("{}", sweep.render_table());

    // 3. the same report value, machine-readable: canonical JSON (and
    //    sweep.render_csv() for spreadsheets)
    print!("{}", sweep.render_json());
    Ok(())
}
