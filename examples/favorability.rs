//! CiM-favorability analysis (paper §VI-C): which programs benefit, and
//! why — MACR vs energy improvement, with the L1/L2 locality breakdown and
//! the Jain-et-al. [23] baseline classifier for comparison.
//!
//! Run: `cargo run --release --example favorability`

use eva_cim::analyzer::{analyze, baseline, LocalityRule};
use eva_cim::config::SystemConfig;
use eva_cim::profiler::{evaluate_native, ProfileInputs};
use eva_cim::reshape::reshape;
use eva_cim::sim::{simulate, Limits};
use eva_cim::util::TextTable;
use eva_cim::workloads;

fn main() -> anyhow::Result<()> {
    let cfg = SystemConfig::preset("c1").unwrap();
    let mut t = TextTable::new(
        "CiM favorability (config c1, SRAM)",
        &["bench", "MACR", "L1 share", "Jain CC%", "E-impr", "verdict"],
    );
    for bench in workloads::NAMES {
        let prog = workloads::build(bench, 0, 42).unwrap();
        let trace = simulate(&prog, &cfg, Limits::default())?;
        let an = analyze(&trace, &cfg, LocalityRule::AnyCache);
        let jain = baseline::classify(&trace.ciq);
        let reshaped = reshape(&trace, &an.selection, &cfg);
        let res = evaluate_native(&ProfileInputs::new(&cfg, &reshaped));
        let verdict = if an.macr.ratio() > 0.5 && res.improvement > 1.15 {
            "CiM-favorable"
        } else if res.improvement < 1.05 {
            "CiM-unfavorable"
        } else {
            "marginal"
        };
        t.row(vec![
            workloads::display_name(bench).into(),
            format!("{:.1}%", an.macr.ratio() * 100.0),
            format!("{:.1}%", an.macr.l1_share() * 100.0),
            format!("{:.1}%", jain.cim_fraction() * 100.0),
            format!("{:.2}", res.improvement),
            verdict.into(),
        ]);
    }
    println!("{}", t.render());
    println!("note: a high MACR (>50%) marks a program as CiM-favorable —");
    println!("data-intensive alone is not sufficient (paper finding ii).");
    Ok(())
}
