//! Technology exploration (paper §VI-E + DSE guidance): SRAM vs FeFET on
//! a chosen workload, including the *sensitivity* artifact — the gradient
//! of system energy w.r.t. cache capacity computed by jax.grad and served
//! through PJRT to steer the design search.
//!
//! Run: `cargo run --release --example technology_explorer` (needs artifacts)

use eva_cim::analyzer::{analyze, LocalityRule};
use eva_cim::config::{SystemConfig, Technology};
use eva_cim::profiler::ProfileInputs;
use eva_cim::reshape::reshape;
use eva_cim::runtime::PjrtRuntime;
use eva_cim::sim::{simulate, Limits};
use eva_cim::util::TextTable;

fn main() -> anyhow::Result<()> {
    let bench = std::env::args().nth(1).unwrap_or_else(|| "m2d".into());
    let mut rt = match PjrtRuntime::load(&PjrtRuntime::default_dir()) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("this example needs the AOT artifacts: {e:#}");
            eprintln!("run `make artifacts` first");
            return Ok(());
        }
    };
    println!("PJRT platform: {}", rt.platform());

    let mut inputs = Vec::new();
    let mut labels = Vec::new();
    // the AOT artifacts cover the frozen SRAM/FeFET tech table; registry
    // technologies (rram, stt-mram, TOML customs) need the native backend
    // — see `eva-cim explore`
    for tech in [Technology::SRAM, Technology::FEFET] {
        for (preset, _) in [("c1", 0), ("c2", 1), ("c3", 2)] {
            let cfg = SystemConfig::preset(preset).unwrap().with_tech(tech);
            let prog = eva_cim::workloads::build(&bench, 0, 42).unwrap();
            let trace = simulate(&prog, &cfg, Limits::default())?;
            let an = analyze(&trace, &cfg, LocalityRule::AnyCache);
            let reshaped = reshape(&trace, &an.selection, &cfg);
            inputs.push(ProfileInputs::new(&cfg, &reshaped));
            labels.push(format!("{preset}/{}", tech.name()));
        }
    }
    let results = rt.evaluate_profile(&inputs)?;
    let (g1, g2) = rt.sensitivity(&inputs)?;

    let mut t = TextTable::new(
        &format!("technology exploration: {bench}"),
        &["config", "E-impr", "speedup", "dE/dcap(L1)", "dE/dcap(L2)"],
    );
    for i in 0..labels.len() {
        t.row(vec![
            labels[i].clone(),
            format!("{:.2}", results[i].improvement),
            format!("{:.2}", results[i].speedup),
            format!("{:+.2e}", g1[i][0]),
            format!("{:+.2e}", g2[i][0]),
        ]);
    }
    println!("{}", t.render());
    println!("positive capacity gradients confirm paper finding (iii):");
    println!("larger arrays raise per-op CiM energy — bigger is not better.");
    println!("({} PJRT executions issued)", rt.executions);
    Ok(())
}
